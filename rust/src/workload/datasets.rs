//! Synthetic stand-ins for the evaluation datasets
//! (DESIGN.md §Substitutions):
//!
//! * **ShareGPT-4o-like** — 50K-image-style conversational data:
//!   *higher-resolution images*, moderate text prompts. The paper uses
//!   this as its visually-intensive workload.
//! * **VisualWebInstruct-like** — web-scraped instruction data: *longer
//!   text inputs*, smaller images.
//! * **VideoChat-like** — video understanding traffic: few(er) requests
//!   with *huge* vision-token counts (a clip is tens of encode chunks).
//! * **VoiceAssistant-like** — conversational audio: short clips, short
//!   prompts/outputs, tight TTFT expectations.
//! * **MixedModal** — all four modalities in one trace, the N-way
//!   modality-group workload.
//!
//! All mix text-only and media-bearing requests; media content and text
//! prefixes are drawn from Zipf-distributed pools so real-world
//! redundancy (repeated images/clips, shared system prompts) is present
//! for the unified-prefix-cache experiments.

use super::arrival::{ArrivalProcess, FlashCrowdProcess};
use super::{MediaRef, Request};
use crate::util::rng::Rng;

/// Arrival-time shape stamped by [`DatasetSpec::sample_trace`]. The
/// historical presets are all `Poisson`, and that arm reproduces the
/// old hard-coded path stream-for-stream, so their traces (and the
/// driver-contract digests pinned on them) are unchanged.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalKind {
    /// Constant-rate Poisson at the trace's target QPS.
    Poisson,
    /// `multiplier`× the target QPS inside
    /// `[start_s, start_s + duration_s)`, target QPS elsewhere.
    FlashCrowd { start_s: f64, duration_s: f64, multiplier: f64 },
}

/// Distributional description of a dataset.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: String,
    /// Arrival shape used when sampling complete traces.
    pub arrival: ArrivalKind,
    /// Fraction of requests that carry >=1 media attachment.
    pub multimodal_fraction: f64,
    /// Text prompt length ~ LogNormal(mu, sigma), clamped.
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    pub prompt_max: usize,
    /// Output length ~ LogNormal(mu, sigma), clamped.
    pub output_mu: f64,
    pub output_sigma: f64,
    pub output_max: usize,
    /// Image edge ~ LogNormal(mu, sigma) pixels, clamped. Also the
    /// resolution distribution of video frames.
    pub image_edge_mu: f64,
    pub image_edge_sigma: f64,
    pub image_edge_min: usize,
    pub image_edge_max: usize,
    /// P(second image | image-bearing), applied repeatedly (geometric).
    pub extra_image_p: f64,
    /// Distinct image pool size + Zipf exponent (content redundancy).
    pub image_pool: usize,
    pub image_zipf_s: f64,
    /// Of media-bearing requests, fraction carrying a video clip and
    /// fraction carrying an audio clip (the rest carry images).
    pub video_fraction: f64,
    pub audio_fraction: f64,
    /// Video length in frames ~ LogNormal(mu, sigma), clamped.
    pub video_frames_mu: f64,
    pub video_frames_sigma: f64,
    pub video_frames_max: usize,
    /// Distinct video content pool (Zipf with `image_zipf_s`).
    pub video_pool: usize,
    /// Audio duration in ms ~ LogNormal(mu, sigma), clamped.
    pub audio_ms_mu: f64,
    pub audio_ms_sigma: f64,
    pub audio_ms_max: usize,
    /// Distinct audio content pool (Zipf with `image_zipf_s`).
    pub audio_pool: usize,
    /// Distinct shared-prefix pool + prefix token length range.
    pub prefix_pool: usize,
    pub prefix_zipf_s: f64,
    pub prefix_tokens_range: (usize, usize),
    /// Fraction of requests that start with a shared prefix.
    pub shared_prefix_fraction: f64,
}

impl DatasetSpec {
    /// Image-only defaults for the video/audio knobs (used by the two
    /// original presets, which carry images exclusively).
    fn no_av() -> (f64, f64, f64, f64, usize, usize, f64, f64, usize, usize) {
        // (video_frac, audio_frac, vframes_mu, vframes_sigma, vframes_max,
        //  video_pool, audio_mu, audio_sigma, audio_max, audio_pool)
        (0.0, 0.0, 3.9, 0.8, 192, 64, 7.9, 0.6, 15_000, 64)
    }

    /// ShareGPT-4o-like: high-resolution images, moderate text.
    /// Medians: prompt ≈ 150 tokens, output ≈ 180, image edge ≈ 900 px.
    pub fn sharegpt4o() -> DatasetSpec {
        let (vf, af, vmu, vsig, vmax, vpool, amu, asig, amax, apool) = Self::no_av();
        DatasetSpec {
            name: "ShareGPT-4o".to_string(),
            arrival: ArrivalKind::Poisson,
            multimodal_fraction: 0.55,
            prompt_mu: 5.0,
            prompt_sigma: 0.9,
            prompt_max: 4096,
            output_mu: 5.2,
            output_sigma: 0.8,
            output_max: 2048,
            image_edge_mu: 6.8,
            image_edge_sigma: 0.35,
            image_edge_min: 336,
            image_edge_max: 2048,
            extra_image_p: 0.15,
            image_pool: 2000,
            image_zipf_s: 1.05,
            video_fraction: vf,
            audio_fraction: af,
            video_frames_mu: vmu,
            video_frames_sigma: vsig,
            video_frames_max: vmax,
            video_pool: vpool,
            audio_ms_mu: amu,
            audio_ms_sigma: asig,
            audio_ms_max: amax,
            audio_pool: apool,
            prefix_pool: 24,
            prefix_zipf_s: 1.2,
            prefix_tokens_range: (64, 512),
            shared_prefix_fraction: 0.45,
        }
    }

    /// VisualWebInstruct-like: long text inputs, smaller images.
    /// Medians: prompt ≈ 500 tokens, output ≈ 250, image edge ≈ 550 px.
    pub fn visualwebinstruct() -> DatasetSpec {
        DatasetSpec {
            name: "VisualWebInstruct".to_string(),
            multimodal_fraction: 0.45,
            prompt_mu: 6.2,
            prompt_sigma: 1.0,
            prompt_max: 8192,
            output_mu: 5.5,
            output_sigma: 0.7,
            output_max: 2048,
            image_edge_mu: 6.3,
            image_edge_sigma: 0.4,
            image_edge_min: 224,
            image_edge_max: 1344,
            extra_image_p: 0.25,
            image_pool: 4000,
            image_zipf_s: 1.0,
            prefix_pool: 40,
            prefix_zipf_s: 1.1,
            prefix_tokens_range: (128, 768),
            shared_prefix_fraction: 0.5,
            ..Self::sharegpt4o()
        }
    }

    /// VideoChat-like: video understanding traffic — short prompts, huge
    /// per-request vision-token counts (a median clip is tens of encode
    /// chunks), hot clip redundancy. The workload where chunked
    /// non-blocking encoding earns its keep.
    pub fn video_chat() -> DatasetSpec {
        DatasetSpec {
            name: "VideoChat".to_string(),
            multimodal_fraction: 0.85,
            prompt_mu: 4.3,
            prompt_sigma: 0.7,
            prompt_max: 2048,
            output_mu: 5.1,
            output_sigma: 0.7,
            output_max: 1024,
            // Video frame resolution (also used for the few images).
            image_edge_mu: 6.3,
            image_edge_sigma: 0.3,
            image_edge_min: 224,
            image_edge_max: 1024,
            extra_image_p: 0.05,
            image_pool: 500,
            image_zipf_s: 1.05,
            video_fraction: 0.9,
            audio_fraction: 0.0,
            video_frames_mu: 4.2, // median ≈ 67 frames
            video_frames_sigma: 0.9,
            video_frames_max: 192,
            video_pool: 300,
            prefix_pool: 16,
            prefix_zipf_s: 1.2,
            prefix_tokens_range: (32, 256),
            shared_prefix_fraction: 0.35,
            ..Self::sharegpt4o()
        }
    }

    /// VoiceAssistant-like: conversational audio — short clips, short
    /// prompts and outputs, hot system prompts. Tight-TTFT traffic (see
    /// `Slo::default_for(Modality::Audio)`).
    pub fn voice_assistant() -> DatasetSpec {
        DatasetSpec {
            name: "VoiceAssistant".to_string(),
            multimodal_fraction: 0.75,
            prompt_mu: 3.9,
            prompt_sigma: 0.6,
            prompt_max: 512,
            output_mu: 4.0,
            output_sigma: 0.6,
            output_max: 512,
            video_fraction: 0.0,
            audio_fraction: 1.0,
            audio_ms_mu: 8.3, // median ≈ 4 s
            audio_ms_sigma: 0.6,
            audio_ms_max: 30_000,
            audio_pool: 4000, // mostly-unique utterances
            prefix_pool: 8,
            prefix_zipf_s: 1.3,
            prefix_tokens_range: (64, 256),
            shared_prefix_fraction: 0.7,
            ..Self::sharegpt4o()
        }
    }

    /// Mixed 4-modality trace: text, image, video, and audio requests in
    /// one stream — the N-way modality-group workload.
    pub fn mixed_modality() -> DatasetSpec {
        DatasetSpec {
            name: "MixedModal".to_string(),
            multimodal_fraction: 0.7,
            video_fraction: 0.3,
            audio_fraction: 0.25,
            video_frames_mu: 3.9, // median ≈ 50 frames
            video_frames_sigma: 0.8,
            video_frames_max: 128,
            video_pool: 64,
            audio_ms_mu: 7.9, // median ≈ 2.7 s
            audio_ms_sigma: 0.6,
            audio_ms_max: 15_000,
            audio_pool: 48,
            image_pool: 200,
            ..Self::sharegpt4o()
        }
    }

    /// Mixed-modality content under a flash-crowd arrival shape: 5× the
    /// target QPS for a 20 s window starting at t=10 s. The policy
    /// shoot-out workload — reactive scaling pays the full queue-build
    /// cost before responding, a forecaster can move first.
    pub fn flash_crowd() -> DatasetSpec {
        DatasetSpec {
            name: "FlashCrowd".to_string(),
            arrival: ArrivalKind::FlashCrowd {
                start_s: 10.0,
                duration_s: 20.0,
                multiplier: 5.0,
            },
            ..Self::mixed_modality()
        }
    }

    /// 50/50 mixture used by the Fig 8 ablation ("sampling from a mixed
    /// dataset composed of two distinct sources").
    pub fn mixed() -> (DatasetSpec, DatasetSpec) {
        (DatasetSpec::sharegpt4o(), DatasetSpec::visualwebinstruct())
    }

    /// The dataset registry: look up a preset by CLI name. `None` means
    /// the name is unknown — callers must error out, not fall back.
    pub fn by_name(name: &str) -> Option<DatasetSpec> {
        match name {
            "sharegpt" | "sharegpt4o" => Some(Self::sharegpt4o()),
            "vwi" | "visualwebinstruct" => Some(Self::visualwebinstruct()),
            "video-chat" | "videochat" => Some(Self::video_chat()),
            "voice-assistant" | "voice" => Some(Self::voice_assistant()),
            "mixed-modal" | "mixed" => Some(Self::mixed_modality()),
            "flash-crowd" | "flashcrowd" => Some(Self::flash_crowd()),
            _ => None,
        }
    }

    /// Canonical registry names (one per preset), for error messages.
    pub const REGISTRY: [&'static str; 6] =
        ["sharegpt", "vwi", "video-chat", "voice-assistant", "mixed-modal", "flash-crowd"];

    fn sample_len(rng: &mut Rng, mu: f64, sigma: f64, max: usize) -> usize {
        (rng.lognormal(mu, sigma).round() as usize).clamp(4, max)
    }

    /// Sample a content-determined frame/image edge for `content_id`
    /// from pool-salted stream `salt`. Dimensions are a *deterministic
    /// property of the content* (repeated transmissions of the same
    /// media have the same pixels/samples).
    fn content_rng(&self, content_id: u64, pool: usize, salt: u64) -> Rng {
        Rng::new(content_id ^ ((pool as u64) << 32) ^ salt)
    }

    /// Draw one request (arrival time filled by the arrival process).
    pub fn sample(&self, rng: &mut Rng, id: u64) -> Request {
        let prompt_tokens =
            Self::sample_len(rng, self.prompt_mu, self.prompt_sigma, self.prompt_max);
        let output_tokens =
            Self::sample_len(rng, self.output_mu, self.output_sigma, self.output_max);
        let mut media = Vec::new();
        if rng.chance(self.multimodal_fraction) {
            // Media class draw (skipped entirely for image-only specs so
            // their random streams — and existing traces — are unchanged).
            let av = self.video_fraction + self.audio_fraction;
            let class_draw = if av > 0.0 { rng.f64() } else { 1.0 };
            if class_draw < self.video_fraction {
                let content_id = rng.zipf(self.video_pool, self.image_zipf_s) as u64;
                let mut vrng = self.content_rng(content_id, self.video_pool, 0x71DE0);
                let edge = (vrng
                    .lognormal(self.image_edge_mu, self.image_edge_sigma)
                    .round() as usize)
                    .clamp(self.image_edge_min, self.image_edge_max);
                let h = ((edge as f64) * vrng.range_f64(0.55, 1.0)) as usize;
                let frames = (vrng
                    .lognormal(self.video_frames_mu, self.video_frames_sigma)
                    .round() as usize)
                    .clamp(8, self.video_frames_max.max(8));
                media.push(MediaRef::video(
                    edge,
                    h.clamp(self.image_edge_min, self.image_edge_max),
                    frames,
                    content_id,
                ));
            } else if class_draw < self.video_fraction + self.audio_fraction {
                let content_id = rng.zipf(self.audio_pool, self.image_zipf_s) as u64;
                let mut arng = self.content_rng(content_id, self.audio_pool, 0xAD10);
                let ms = (arng.lognormal(self.audio_ms_mu, self.audio_ms_sigma).round()
                    as usize)
                    .clamp(500, self.audio_ms_max.max(500));
                media.push(MediaRef::audio(ms, 16_000, content_id));
            } else {
                loop {
                    let content_id = rng.zipf(self.image_pool, self.image_zipf_s) as u64;
                    let mut irng = self.content_rng(content_id, self.image_pool, 0x1A6E);
                    let edge = (irng
                        .lognormal(self.image_edge_mu, self.image_edge_sigma)
                        .round() as usize)
                        .clamp(self.image_edge_min, self.image_edge_max);
                    // Mild aspect-ratio variation, also content-determined.
                    let h = ((edge as f64) * irng.range_f64(0.75, 1.3)) as usize;
                    media.push(MediaRef::image(
                        edge,
                        h.clamp(self.image_edge_min, self.image_edge_max),
                        content_id,
                    ));
                    if media.len() >= 8 || !rng.chance(self.extra_image_p) {
                        break;
                    }
                }
            }
        }
        let (prefix_id, prefix_tokens) = if rng.chance(self.shared_prefix_fraction) {
            let pid = rng.zipf(self.prefix_pool, self.prefix_zipf_s) as u64;
            // Deterministic per-prefix length so identical ids share an
            // identical token span (required for cache correctness).
            let (lo, hi) = self.prefix_tokens_range;
            let span = lo + (pid as usize * 2654435761 % (hi - lo + 1));
            (pid + 1, span.min(prompt_tokens))
        } else {
            (0, 0)
        };
        Request {
            id,
            arrival: 0.0,
            prompt_tokens,
            output_tokens,
            media: media.into(),
            prefix_id,
            prefix_tokens,
        }
    }

    /// Generate `n` requests (arrivals at 0; combine with an arrival
    /// process from [`super::arrival`]).
    pub fn generate(&self, rng: &mut Rng, n: usize) -> Vec<Request> {
        (0..n).map(|i| self.sample(rng, i as u64)).collect()
    }

    /// Generate a complete trace — `n` requests with arrivals at target
    /// rate `qps` under the spec's [`ArrivalKind`] — from the
    /// SplitMix64-forked seed stream `(master_seed, stream_id)` (see
    /// [`Rng::fork_stream`]). Distinct stream ids yield statistically
    /// independent traces; the same pair reproduces the same trace, so
    /// sweep runs can be re-executed individually and compared
    /// bit-for-bit against a parallel run.
    pub fn sample_trace(
        &self,
        master_seed: u64,
        stream_id: u64,
        n: usize,
        qps: f64,
    ) -> Vec<Request> {
        let mut rng = Rng::fork_stream(master_seed, stream_id);
        let mut reqs = self.generate(&mut rng, n);
        match self.arrival {
            ArrivalKind::Poisson => {
                super::arrival::poisson_arrivals(&mut rng, &mut reqs, qps);
            }
            ArrivalKind::FlashCrowd { start_s, duration_s, multiplier } => {
                let p = FlashCrowdProcess {
                    base_qps: qps,
                    crowd_qps: qps * multiplier,
                    start_s,
                    duration_s,
                };
                p.stamp_arrivals(&mut rng, &mut reqs);
            }
        }
        reqs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::util::stats;
    use crate::workload::{MediaPayload, Modality};

    #[test]
    fn sharegpt_has_higher_resolution_images() {
        let mut rng = Rng::new(1);
        let sg = DatasetSpec::sharegpt4o().generate(&mut rng, 4000);
        let vw = DatasetSpec::visualwebinstruct().generate(&mut rng, 4000);
        let avg_edge = |rs: &[Request]| {
            let e: Vec<f64> = rs
                .iter()
                .flat_map(|r| r.media.iter())
                .filter_map(|m| match m.payload {
                    MediaPayload::Image { width, .. } => Some(width as f64),
                    _ => None,
                })
                .collect();
            stats::mean(&e)
        };
        assert!(
            avg_edge(&sg) > avg_edge(&vw) + 100.0,
            "sharegpt {} vs vwi {}",
            avg_edge(&sg),
            avg_edge(&vw)
        );
    }

    #[test]
    fn visualwebinstruct_has_longer_text() {
        let mut rng = Rng::new(2);
        let sg = DatasetSpec::sharegpt4o().generate(&mut rng, 4000);
        let vw = DatasetSpec::visualwebinstruct().generate(&mut rng, 4000);
        let avg = |rs: &[Request]| {
            stats::mean(&rs.iter().map(|r| r.prompt_tokens as f64).collect::<Vec<_>>())
        };
        assert!(avg(&vw) > 1.5 * avg(&sg));
    }

    #[test]
    fn multimodal_fraction_close_to_spec() {
        let mut rng = Rng::new(3);
        let spec = DatasetSpec::sharegpt4o();
        let rs = spec.generate(&mut rng, 8000);
        let frac = rs.iter().filter(|r| !r.media.is_empty()).count() as f64
            / rs.len() as f64;
        assert!((frac - spec.multimodal_fraction).abs() < 0.03, "frac={frac}");
    }

    #[test]
    fn multimodal_context_longer_than_text_only() {
        // Paper Fig 1c: multimodal requests have much longer contexts.
        let mut rng = Rng::new(4);
        let model = presets::qwen25_vl_7b();
        let rs = DatasetSpec::sharegpt4o().generate(&mut rng, 4000);
        let (mut mm, mut txt) = (Vec::new(), Vec::new());
        for r in &rs {
            let len = r.input_len(&model) as f64;
            if r.media.is_empty() {
                txt.push(len);
            } else {
                mm.push(len);
            }
        }
        assert!(stats::mean(&mm) > 4.0 * stats::mean(&txt));
    }

    #[test]
    fn image_content_redundancy_exists() {
        let mut rng = Rng::new(5);
        let rs = DatasetSpec::sharegpt4o().generate(&mut rng, 3000);
        let ids: Vec<u64> =
            rs.iter().flat_map(|r| r.media.iter().map(|m| m.content_id)).collect();
        let mut uniq = ids.clone();
        uniq.sort();
        uniq.dedup();
        assert!(
            uniq.len() < ids.len() / 2,
            "expected heavy reuse: {} unique of {}",
            uniq.len(),
            ids.len()
        );
    }

    #[test]
    fn shared_prefixes_deterministic_per_id() {
        let mut rng = Rng::new(6);
        let spec = DatasetSpec::sharegpt4o();
        let rs = spec.generate(&mut rng, 5000);
        let mut seen = 0;
        for r in rs.iter().filter(|r| r.prefix_id != 0) {
            // Span is a pure function of prefix_id, clamped by the prompt.
            let (lo, hi) = spec.prefix_tokens_range;
            let pid = r.prefix_id - 1;
            let expected =
                (lo + (pid as usize * 2654435761 % (hi - lo + 1))).min(r.prompt_tokens);
            assert_eq!(r.prefix_tokens, expected, "prefix span mismatch");
            seen += 1;
        }
        assert!(seen > 100);
    }

    #[test]
    fn lengths_within_bounds() {
        let mut rng = Rng::new(7);
        for spec in [DatasetSpec::sharegpt4o(), DatasetSpec::visualwebinstruct()] {
            for r in spec.generate(&mut rng, 2000) {
                assert!(r.prompt_tokens <= spec.prompt_max);
                assert!(r.output_tokens <= spec.output_max);
                for m in r.media.iter() {
                    if let MediaPayload::Image { width, .. } = m.payload {
                        assert!(width >= spec.image_edge_min);
                        assert!(width <= spec.image_edge_max);
                    }
                }
            }
        }
    }

    #[test]
    fn video_chat_is_video_heavy_with_huge_media_tokens() {
        let mut rng = Rng::new(8);
        let model = presets::qwen25_vl_7b();
        let spec = DatasetSpec::video_chat();
        let rs = spec.generate(&mut rng, 2000);
        let vids = rs.iter().filter(|r| r.modality() == Modality::Video).count();
        assert!(
            vids as f64 > 0.6 * rs.len() as f64,
            "video-chat must be video-dominated: {vids}/{}",
            rs.len()
        );
        // Median video request carries far more media tokens than a
        // single high-res image (the "huge vision-token counts" regime).
        let mut vt: Vec<f64> = rs
            .iter()
            .filter(|r| r.modality() == Modality::Video)
            .map(|r| r.media_tokens(&model) as f64)
            .collect();
        vt.sort_by(f64::total_cmp);
        let median = vt[vt.len() / 2];
        assert!(
            median > 1.5 * model.image_tokens(904, 904) as f64,
            "median video tokens {median}"
        );
        // Clips span multiple encode chunks.
        let multi_chunk = rs.iter().any(|r| {
            r.media.iter().any(|m| {
                let mut n = 0;
                m.encode_jobs(&model, |_| n += 1);
                n > 2
            })
        });
        assert!(multi_chunk, "video-chat must produce multi-chunk clips");
    }

    #[test]
    fn voice_assistant_is_short_audio() {
        let mut rng = Rng::new(9);
        let model = presets::qwen25_vl_7b();
        let spec = DatasetSpec::voice_assistant();
        let rs = spec.generate(&mut rng, 2000);
        let auds = rs.iter().filter(|r| r.modality() == Modality::Audio).count();
        assert!(auds as f64 > 0.6 * rs.len() as f64, "audio-dominated: {auds}");
        // Inputs are short relative to image traffic.
        let mean_in = stats::mean(
            &rs.iter().map(|r| r.input_len(&model) as f64).collect::<Vec<_>>(),
        );
        assert!(mean_in < 1000.0, "voice inputs must be short, got {mean_in}");
    }

    #[test]
    fn mixed_modality_covers_all_four() {
        let mut rng = Rng::new(10);
        let rs = DatasetSpec::mixed_modality().generate(&mut rng, 3000);
        let mut counts = [0usize; Modality::COUNT];
        for r in &rs {
            counts[r.modality().index()] += 1;
        }
        for (m, &c) in Modality::ALL.iter().zip(&counts) {
            assert!(
                c as f64 > 0.05 * rs.len() as f64,
                "{} underrepresented: {c}/{}",
                m.name(),
                rs.len()
            );
        }
    }

    #[test]
    fn media_shape_is_content_determined() {
        // Same content id ⇒ identical payload (required for cache
        // correctness): collect by id and compare.
        let mut rng = Rng::new(11);
        let rs = DatasetSpec::mixed_modality().generate(&mut rng, 4000);
        let mut by_key = std::collections::HashMap::new();
        for m in rs.iter().flat_map(|r| r.media.iter()) {
            let key = (std::mem::discriminant(&m.payload), m.content_id);
            let prev = by_key.insert(key, m.payload);
            if let Some(p) = prev {
                assert_eq!(p, m.payload, "content id {} shape drifted", m.content_id);
            }
        }
    }

    #[test]
    fn sample_trace_reproducible_and_streams_independent() {
        let spec = DatasetSpec::sharegpt4o();
        let a = spec.sample_trace(42, 3, 200, 5.0);
        let b = spec.sample_trace(42, 3, 200, 5.0);
        assert_eq!(a.len(), 200);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
            assert_eq!(x.output_tokens, y.output_tokens);
            assert_eq!(x.media.len(), y.media.len());
        }
        // Distinct stream ids from the same master seed give different
        // traces (this is what `seed + i` seeding cannot guarantee).
        let c = spec.sample_trace(42, 4, 200, 5.0);
        let same = a.iter().zip(&c).filter(|(x, y)| x.arrival == y.arrival).count();
        assert!(same < 5, "streams 3 and 4 nearly identical: {same}/200 equal arrivals");
        // Arrivals are strictly increasing (valid Poisson stamping).
        assert!(a.windows(2).all(|w| w[0].arrival < w[1].arrival));
    }

    #[test]
    fn registry_resolves_every_name_and_rejects_unknown() {
        for name in DatasetSpec::REGISTRY {
            assert!(DatasetSpec::by_name(name).is_some(), "registry name {name}");
        }
        assert!(DatasetSpec::by_name("sharegpt4o").is_some(), "alias");
        assert!(DatasetSpec::by_name("not-a-dataset").is_none());
    }

    #[test]
    fn flash_crowd_trace_spikes_inside_window() {
        let spec = DatasetSpec::flash_crowd();
        assert!(matches!(spec.arrival, ArrivalKind::FlashCrowd { .. }));
        // Every other preset keeps the Poisson shape (and therefore the
        // historical trace streams).
        for name in ["sharegpt", "vwi", "video-chat", "voice-assistant", "mixed-modal"] {
            assert_eq!(DatasetSpec::by_name(name).unwrap().arrival, ArrivalKind::Poisson);
        }
        let trace = spec.sample_trace(42, 0, 2000, 4.0);
        assert!(trace.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        // 5x multiplier on a 4 qps base: ~20 qps inside [10, 30).
        let n_in = trace
            .iter()
            .filter(|r| (10.0..30.0).contains(&r.arrival))
            .count() as f64;
        assert!((n_in / 20.0 - 20.0).abs() < 5.0, "crowd rate {}", n_in / 20.0);
        // Reproducible: same (seed, stream) pair gives identical stamps.
        let again = spec.sample_trace(42, 0, 2000, 4.0);
        assert!(trace.iter().zip(&again).all(|(a, b)| a.arrival == b.arrival));
    }
}
