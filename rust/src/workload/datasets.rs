//! Synthetic stand-ins for the paper's two evaluation datasets
//! (DESIGN.md §Substitutions):
//!
//! * **ShareGPT-4o-like** — 50K-image-style conversational data:
//!   *higher-resolution images*, moderate text prompts. The paper uses
//!   this as its visually-intensive workload.
//! * **VisualWebInstruct-like** — web-scraped instruction data: *longer
//!   text inputs*, smaller images.
//!
//! Both mix text-only and multimodal requests; image content and text
//! prefixes are drawn from Zipf-distributed pools so real-world
//! redundancy (repeated images, shared system prompts) is present for
//! the unified-prefix-cache experiments.

use super::{ImageRef, Request};
use crate::util::rng::Rng;

/// Distributional description of a dataset.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: String,
    /// Fraction of requests that carry >=1 image.
    pub multimodal_fraction: f64,
    /// Text prompt length ~ LogNormal(mu, sigma), clamped.
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    pub prompt_max: usize,
    /// Output length ~ LogNormal(mu, sigma), clamped.
    pub output_mu: f64,
    pub output_sigma: f64,
    pub output_max: usize,
    /// Image edge ~ LogNormal(mu, sigma) pixels, clamped.
    pub image_edge_mu: f64,
    pub image_edge_sigma: f64,
    pub image_edge_min: usize,
    pub image_edge_max: usize,
    /// P(second image | multimodal), applied repeatedly (geometric).
    pub extra_image_p: f64,
    /// Distinct image pool size + Zipf exponent (content redundancy).
    pub image_pool: usize,
    pub image_zipf_s: f64,
    /// Distinct shared-prefix pool + prefix token length range.
    pub prefix_pool: usize,
    pub prefix_zipf_s: f64,
    pub prefix_tokens_range: (usize, usize),
    /// Fraction of requests that start with a shared prefix.
    pub shared_prefix_fraction: f64,
}

impl DatasetSpec {
    /// ShareGPT-4o-like: high-resolution images, moderate text.
    /// Medians: prompt ≈ 150 tokens, output ≈ 180, image edge ≈ 900 px.
    pub fn sharegpt4o() -> DatasetSpec {
        DatasetSpec {
            name: "ShareGPT-4o".to_string(),
            multimodal_fraction: 0.55,
            prompt_mu: 5.0,
            prompt_sigma: 0.9,
            prompt_max: 4096,
            output_mu: 5.2,
            output_sigma: 0.8,
            output_max: 2048,
            image_edge_mu: 6.8,
            image_edge_sigma: 0.35,
            image_edge_min: 336,
            image_edge_max: 2048,
            extra_image_p: 0.15,
            image_pool: 2000,
            image_zipf_s: 1.05,
            prefix_pool: 24,
            prefix_zipf_s: 1.2,
            prefix_tokens_range: (64, 512),
            shared_prefix_fraction: 0.45,
        }
    }

    /// VisualWebInstruct-like: long text inputs, smaller images.
    /// Medians: prompt ≈ 500 tokens, output ≈ 250, image edge ≈ 550 px.
    pub fn visualwebinstruct() -> DatasetSpec {
        DatasetSpec {
            name: "VisualWebInstruct".to_string(),
            multimodal_fraction: 0.45,
            prompt_mu: 6.2,
            prompt_sigma: 1.0,
            prompt_max: 8192,
            output_mu: 5.5,
            output_sigma: 0.7,
            output_max: 2048,
            image_edge_mu: 6.3,
            image_edge_sigma: 0.4,
            image_edge_min: 224,
            image_edge_max: 1344,
            extra_image_p: 0.25,
            image_pool: 4000,
            image_zipf_s: 1.0,
            prefix_pool: 40,
            prefix_zipf_s: 1.1,
            prefix_tokens_range: (128, 768),
            shared_prefix_fraction: 0.5,
        }
    }

    /// 50/50 mixture used by the Fig 8 ablation ("sampling from a mixed
    /// dataset composed of two distinct sources").
    pub fn mixed() -> (DatasetSpec, DatasetSpec) {
        (DatasetSpec::sharegpt4o(), DatasetSpec::visualwebinstruct())
    }

    fn sample_len(rng: &mut Rng, mu: f64, sigma: f64, max: usize) -> usize {
        (rng.lognormal(mu, sigma).round() as usize).clamp(4, max)
    }

    /// Draw one request (arrival time filled by the arrival process).
    pub fn sample(&self, rng: &mut Rng, id: u64) -> Request {
        let prompt_tokens =
            Self::sample_len(rng, self.prompt_mu, self.prompt_sigma, self.prompt_max);
        let output_tokens =
            Self::sample_len(rng, self.output_mu, self.output_sigma, self.output_max);
        let mut images = Vec::new();
        if rng.chance(self.multimodal_fraction) {
            loop {
                let content_id = rng.zipf(self.image_pool, self.image_zipf_s) as u64;
                // Dimensions are a *deterministic property of the image
                // content* (repeated transmissions of the same image have
                // the same pixels), drawn from the dataset's resolution
                // distribution via a content-seeded stream.
                let mut irng =
                    Rng::new(content_id ^ ((self.image_pool as u64) << 32) ^ 0x1A6E);
                let edge = (irng
                    .lognormal(self.image_edge_mu, self.image_edge_sigma)
                    .round() as usize)
                    .clamp(self.image_edge_min, self.image_edge_max);
                // Mild aspect-ratio variation, also content-determined.
                let h = ((edge as f64) * irng.range_f64(0.75, 1.3)) as usize;
                images.push(ImageRef {
                    width: edge,
                    height: h.clamp(self.image_edge_min, self.image_edge_max),
                    content_id,
                });
                if images.len() >= 8 || !rng.chance(self.extra_image_p) {
                    break;
                }
            }
        }
        let (prefix_id, prefix_tokens) = if rng.chance(self.shared_prefix_fraction) {
            let pid = rng.zipf(self.prefix_pool, self.prefix_zipf_s) as u64;
            // Deterministic per-prefix length so identical ids share an
            // identical token span (required for cache correctness).
            let (lo, hi) = self.prefix_tokens_range;
            let span = lo + (pid as usize * 2654435761 % (hi - lo + 1));
            (pid + 1, span.min(prompt_tokens))
        } else {
            (0, 0)
        };
        Request {
            id,
            arrival: 0.0,
            prompt_tokens,
            output_tokens,
            images: images.into(),
            prefix_id,
            prefix_tokens,
        }
    }

    /// Generate `n` requests (arrivals at 0; combine with an arrival
    /// process from [`super::arrival`]).
    pub fn generate(&self, rng: &mut Rng, n: usize) -> Vec<Request> {
        (0..n).map(|i| self.sample(rng, i as u64)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::util::stats;

    #[test]
    fn sharegpt_has_higher_resolution_images() {
        let mut rng = Rng::new(1);
        let sg = DatasetSpec::sharegpt4o().generate(&mut rng, 4000);
        let vw = DatasetSpec::visualwebinstruct().generate(&mut rng, 4000);
        let avg_edge = |rs: &[Request]| {
            let e: Vec<f64> = rs
                .iter()
                .flat_map(|r| r.images.iter().map(|i| i.width as f64))
                .collect();
            stats::mean(&e)
        };
        assert!(
            avg_edge(&sg) > avg_edge(&vw) + 100.0,
            "sharegpt {} vs vwi {}",
            avg_edge(&sg),
            avg_edge(&vw)
        );
    }

    #[test]
    fn visualwebinstruct_has_longer_text() {
        let mut rng = Rng::new(2);
        let sg = DatasetSpec::sharegpt4o().generate(&mut rng, 4000);
        let vw = DatasetSpec::visualwebinstruct().generate(&mut rng, 4000);
        let avg = |rs: &[Request]| {
            stats::mean(&rs.iter().map(|r| r.prompt_tokens as f64).collect::<Vec<_>>())
        };
        assert!(avg(&vw) > 1.5 * avg(&sg));
    }

    #[test]
    fn multimodal_fraction_close_to_spec() {
        let mut rng = Rng::new(3);
        let spec = DatasetSpec::sharegpt4o();
        let rs = spec.generate(&mut rng, 8000);
        let frac = rs.iter().filter(|r| !r.images.is_empty()).count() as f64
            / rs.len() as f64;
        assert!((frac - spec.multimodal_fraction).abs() < 0.03, "frac={frac}");
    }

    #[test]
    fn multimodal_context_longer_than_text_only() {
        // Paper Fig 1c: multimodal requests have much longer contexts.
        let mut rng = Rng::new(4);
        let model = presets::qwen25_vl_7b();
        let rs = DatasetSpec::sharegpt4o().generate(&mut rng, 4000);
        let (mut mm, mut txt) = (Vec::new(), Vec::new());
        for r in &rs {
            let len = r.input_len(&model) as f64;
            if r.images.is_empty() {
                txt.push(len);
            } else {
                mm.push(len);
            }
        }
        assert!(stats::mean(&mm) > 4.0 * stats::mean(&txt));
    }

    #[test]
    fn image_content_redundancy_exists() {
        let mut rng = Rng::new(5);
        let rs = DatasetSpec::sharegpt4o().generate(&mut rng, 3000);
        let ids: Vec<u64> =
            rs.iter().flat_map(|r| r.images.iter().map(|i| i.content_id)).collect();
        let mut uniq = ids.clone();
        uniq.sort();
        uniq.dedup();
        assert!(
            uniq.len() < ids.len() / 2,
            "expected heavy reuse: {} unique of {}",
            uniq.len(),
            ids.len()
        );
    }

    #[test]
    fn shared_prefixes_deterministic_per_id() {
        let mut rng = Rng::new(6);
        let spec = DatasetSpec::sharegpt4o();
        let rs = spec.generate(&mut rng, 5000);
        let mut seen = 0;
        for r in rs.iter().filter(|r| r.prefix_id != 0) {
            // Span is a pure function of prefix_id, clamped by the prompt.
            let (lo, hi) = spec.prefix_tokens_range;
            let pid = r.prefix_id - 1;
            let expected =
                (lo + (pid as usize * 2654435761 % (hi - lo + 1))).min(r.prompt_tokens);
            assert_eq!(r.prefix_tokens, expected, "prefix span mismatch");
            seen += 1;
        }
        assert!(seen > 100);
    }

    #[test]
    fn lengths_within_bounds() {
        let mut rng = Rng::new(7);
        for spec in [DatasetSpec::sharegpt4o(), DatasetSpec::visualwebinstruct()] {
            for r in spec.generate(&mut rng, 2000) {
                assert!(r.prompt_tokens <= spec.prompt_max);
                assert!(r.output_tokens <= spec.output_max);
                for img in r.images.iter() {
                    assert!(img.width >= spec.image_edge_min);
                    assert!(img.width <= spec.image_edge_max);
                }
            }
        }
    }
}
