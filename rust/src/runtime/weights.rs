//! weights.bin reader — the counterpart of `python/compile/aot.py`'s
//! `write_weights`: magic "EMMW", u32 count, then per tensor
//! u32 name_len / name / u32 ndim / u64 dims... / f32 data (LE).

use crate::util::error::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// All model weights, by name, plus literal conversion.
pub struct WeightStore {
    pub tensors: HashMap<String, Tensor>,
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("weights.bin truncated at byte {}", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

impl WeightStore {
    pub fn load(path: &Path) -> Result<WeightStore> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("read {} — run `make artifacts`", path.display()))?;
        Self::parse(&bytes)
    }

    pub fn parse(bytes: &[u8]) -> Result<WeightStore> {
        let mut r = Reader { b: bytes, pos: 0 };
        if r.take(4)? != b"EMMW" {
            bail!("bad magic in weights.bin");
        }
        let count = r.u32()? as usize;
        let mut tensors = HashMap::with_capacity(count);
        for _ in 0..count {
            let nlen = r.u32()? as usize;
            let name = String::from_utf8(r.take(nlen)?.to_vec())?;
            let ndim = r.u32()? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(r.u64()? as usize);
            }
            let n: usize = dims.iter().product();
            let raw = r.take(4 * n)?;
            let mut data = vec![0f32; n];
            for (i, chunk) in raw.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
            tensors.insert(name.clone(), Tensor { name, dims, data });
        }
        if r.pos != bytes.len() {
            bail!("trailing bytes in weights.bin");
        }
        Ok(WeightStore { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow!("missing weight tensor {name}"))
    }

    /// Convert a tensor to an XLA literal (f32, row-major).
    pub fn literal(&self, name: &str) -> Result<xla::Literal> {
        let t = self.get(name)?;
        let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
    }

    pub fn total_params(&self) -> usize {
        self.tensors.values().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_bytes() -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(b"EMMW");
        b.extend_from_slice(&2u32.to_le_bytes());
        // tensor "a": shape [2, 3]
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(b"a");
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&2u64.to_le_bytes());
        b.extend_from_slice(&3u64.to_le_bytes());
        for i in 0..6 {
            b.extend_from_slice(&(i as f32).to_le_bytes());
        }
        // tensor "b": scalar-ish shape [1]
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(b"b");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&1u64.to_le_bytes());
        b.extend_from_slice(&7.5f32.to_le_bytes());
        b
    }

    #[test]
    fn parses_valid_file() {
        let ws = WeightStore::parse(&sample_bytes()).unwrap();
        assert_eq!(ws.tensors.len(), 2);
        let a = ws.get("a").unwrap();
        assert_eq!(a.dims, vec![2, 3]);
        assert_eq!(a.data, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(ws.get("b").unwrap().data, vec![7.5]);
        assert_eq!(ws.total_params(), 7);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut b = sample_bytes();
        b[0] = b'X';
        assert!(WeightStore::parse(&b).is_err());
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let b = sample_bytes();
        assert!(WeightStore::parse(&b[..b.len() - 2]).is_err());
        let mut c = b.clone();
        c.push(0);
        assert!(WeightStore::parse(&c).is_err());
    }

    #[test]
    fn missing_tensor_is_error() {
        let ws = WeightStore::parse(&sample_bytes()).unwrap();
        assert!(ws.get("nope").is_err());
    }
}
