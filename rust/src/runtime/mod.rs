//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (HLO text + weights.bin + manifest.json) and executes them on the
//! PJRT CPU client. Python never runs on this path — the Rust binary is
//! self-contained once `artifacts/` exists.

pub mod weights;

use crate::util::json::Json;
use crate::util::error::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

pub use weights::{Tensor, WeightStore};

/// Model geometry from manifest.json (mirrors python/compile/model.py).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub dec_layers: usize,
    pub n_vis: usize,
    pub max_prompt: usize,
    pub s_text: usize,
    pub s_pref: usize,
    pub max_total: usize,
    pub img_size: usize,
    pub seed: u64,
}

/// One compiled graph plus its ordered argument names and its weight
/// literals, materialized once at load time (§Perf: re-building weight
/// literals per call copied ~2.5 MB per decode step).
pub struct Graph {
    pub name: String,
    pub exe: xla::PjRtLoadedExecutable,
    pub arg_names: Vec<String>,
    weights: Vec<xla::Literal>,
}

impl Graph {
    /// Execute with `extras` appended after the cached weights in
    /// manifest order. Returns the flattened output tuple. Arguments are
    /// passed by reference — no literal copies on the hot path.
    pub fn run(&self, _store: &WeightStore, extras: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        debug_assert_eq!(self.weights.len() + extras.len(), self.arg_names.len());
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(self.arg_names.len());
        args.extend(self.weights.iter());
        args.extend(extras.iter().copied());
        let bufs = self.exe.execute::<&xla::Literal>(&args)?;
        let out = bufs[0][0].to_literal_sync()?;
        Ok(out.to_tuple()?)
    }
}

/// Extension: the xla crate's Literal lacks Clone; round-trip through
/// raw data to duplicate one (cheap at tiny-model scale).
pub trait CloneLiteral {
    fn clone_literal(&self) -> Result<xla::Literal>;
}

impl CloneLiteral for xla::Literal {
    fn clone_literal(&self) -> Result<xla::Literal> {
        let shape = self.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match self.ty()? {
            xla::ElementType::F32 => {
                let v: Vec<f32> = self.to_vec()?;
                Ok(xla::Literal::vec1(&v)
                    .reshape(&dims.iter().map(|&d| d as i64).collect::<Vec<_>>())?)
            }
            xla::ElementType::S32 => {
                let v: Vec<i32> = self.to_vec()?;
                if dims.is_empty() {
                    Ok(xla::Literal::scalar(v[0]))
                } else {
                    Ok(xla::Literal::vec1(&v)
                        .reshape(&dims.iter().map(|&d| d as i64).collect::<Vec<_>>())?)
                }
            }
            other => Err(anyhow!("clone_literal: unsupported type {other:?}")),
        }
    }
}

/// The loaded tiny-MLLM runtime: all four graphs + weights.
pub struct Runtime {
    pub meta: ModelMeta,
    pub store: WeightStore,
    pub encode: Graph,
    pub prefill_mm: Graph,
    pub prefill_text: Graph,
    pub decode: Graph,
}

impl Runtime {
    /// Load everything from an artifacts directory, compiling the HLO
    /// text on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| {
                format!("read {}/manifest.json — run `make artifacts`", dir.display())
            })?;
        let manifest = Json::parse(&manifest_text)?;
        let m = manifest.get("model")?;
        let meta = ModelMeta {
            vocab: m.get("vocab")?.as_usize()?,
            d_model: m.get("d_model")?.as_usize()?,
            n_heads: m.get("n_heads")?.as_usize()?,
            dec_layers: m.get("dec_layers")?.as_usize()?,
            n_vis: m.get("n_vis")?.as_usize()?,
            max_prompt: m.get("max_prompt")?.as_usize()?,
            s_text: m.get("s_text")?.as_usize()?,
            s_pref: m.get("s_pref")?.as_usize()?,
            max_total: m.get("max_total")?.as_usize()?,
            img_size: m.get("img_size")?.as_usize()?,
            seed: m.get("seed")?.as_u64()?,
        };
        let store = WeightStore::load(&dir.join("weights.bin"))?;
        let graphs = manifest.get("graphs")?;
        let load_graph = |name: &str| -> Result<Graph> {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            let arg_names = graphs
                .get(name)?
                .get("args")?
                .as_arr()?
                .iter()
                .map(|j| Ok(j.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?;
            // Materialize weight literals once; non-weight extras come
            // from the caller at execute time.
            let weights = arg_names
                .iter()
                .filter(|n| store.tensors.contains_key(n.as_str()))
                .map(|n| store.literal(n))
                .collect::<Result<Vec<_>>>()?;
            Ok(Graph { name: name.to_string(), exe, arg_names, weights })
        };
        let encode = load_graph("encode")?;
        let prefill_mm = load_graph("prefill_mm")?;
        let prefill_text = load_graph("prefill_text")?;
        let decode = load_graph("decode")?;
        Ok(Runtime { meta, store, encode, prefill_mm, prefill_text, decode })
    }

    /// Default artifacts dir (repo-root/artifacts), overridable via env.
    pub fn default_dir() -> PathBuf {
        std::env::var("ELASTICMM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

/// Shared handle used by multi-threaded serving (compiled executables
/// and literals are process-wide; PJRT CPU execution is thread-safe).
pub struct RuntimeCache {
    pub graphs: HashMap<String, Graph>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_and_encodes() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let rt = Runtime::load(&dir).unwrap();
        assert_eq!(rt.meta.vocab, 256);
        let img = vec![0.5f32; rt.meta.img_size * rt.meta.img_size * 3];
        let img_lit = xla::Literal::vec1(&img)
            .reshape(&[rt.meta.img_size as i64, rt.meta.img_size as i64, 3])
            .unwrap();
        let out = rt.encode.run(&rt.store, &[&img_lit]).unwrap();
        assert_eq!(out.len(), 1);
        let vis: Vec<f32> = out[0].to_vec().unwrap();
        assert_eq!(vis.len(), rt.meta.n_vis * rt.meta.d_model);
        assert!(vis.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn encode_is_deterministic() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load(&dir).unwrap();
        let img = vec![0.25f32; rt.meta.img_size * rt.meta.img_size * 3];
        let lit = || {
            xla::Literal::vec1(&img)
                .reshape(&[rt.meta.img_size as i64, rt.meta.img_size as i64, 3])
                .unwrap()
        };
        let a: Vec<f32> = rt.encode.run(&rt.store, &[&lit()]).unwrap()[0].to_vec().unwrap();
        let b: Vec<f32> = rt.encode.run(&rt.store, &[&lit()]).unwrap()[0].to_vec().unwrap();
        assert_eq!(a, b, "bit-identical reruns");
    }
}
