//! Discrete-event simulation core: a virtual clock and a deterministic
//! priority event queue. All serving systems (ElasticMM and the
//! baselines) run on this engine so their comparison is apples-to-apples.
//!
//! [`EventQueue`] is a two-level timing-wheel / calendar-queue hybrid
//! (DESIGN.md §12): near-future events land in fixed-width buckets
//! (width and bucket count adapted from the observed inter-event
//! spacing at each re-anchor), far-future events in an overflow level
//! that cascades down when the wheel rolls over, and the earliest
//! active span is kept in a small min-heap so `pop` and the
//! fast-forward hot call `peek_next_time` are O(1)-ish regardless of
//! how many events are pending. Push and pop are O(1) amortized where
//! the previous global `BinaryHeap` paid O(log n) per operation — the
//! difference that dominates million-request trace replays.
//!
//! Pop order is **provably identical** to a global heap ordered by
//! `(time via f64::total_cmp, insertion seq)`: bucket routing uses
//! `floor((t - origin) / width)`, a weakly monotone function of `t`, so
//! an entry in a lower-indexed bucket (or in the active heap, which
//! only holds entries routed below the activation cursor) is strictly
//! earlier than every entry in a higher-indexed bucket or the overflow
//! level; within the active heap the full total order decides. The
//! original heap implementation is retained verbatim as [`HeapQueue`],
//! the differential-testing oracle
//! (`rust/tests/event_queue_differential.rs`).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Entry in the event queue. Ordered by (time, seq) so simultaneous
/// events pop in insertion order — determinism matters for reproducible
/// experiments.
struct Entry<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time.total_cmp(&other.time).is_eq() && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap behaviour on BinaryHeap (a max-heap).
        // `f64::total_cmp` makes the order total by construction —
        // no `partial_cmp(..).unwrap_or(Equal)` fallback relying on the
        // push-time finiteness assert at a distance.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Operation counters exposed by both queue implementations — the
/// event-queue pressure telemetry surfaced through
/// [`DriverStats`](crate::sim::driver::DriverStats), bench JSON, and
/// the driver's stall-panic diagnostics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueTelemetry {
    /// Total `push`/`push_after` calls.
    pub pushes: u64,
    /// Total successful `pop`s.
    pub pops: u64,
    /// High-water mark of pending events.
    pub peak_pending: usize,
    /// Overflow-level cascades (wheel re-anchors). Always 0 for the
    /// heap oracle.
    pub overflow_cascades: u64,
}

impl QueueTelemetry {
    fn on_push(&mut self, len: usize) {
        self.pushes += 1;
        if len > self.peak_pending {
            self.peak_pending = len;
        }
    }
}

/// Smallest wheel: when few events are pending, a big bucket array
/// would make the activation cursor scan mostly empty buckets.
const MIN_BUCKETS: usize = 16;
/// Largest wheel: bounds cascade-time memory; beyond this the overflow
/// level absorbs the tail and is rescanned once per rollover.
const MAX_BUCKETS: usize = 1 << 16;
/// Floor on the adapted bucket width (an all-ties overflow would
/// otherwise yield width 0 and NaN bucket indices).
const MIN_BUCKET_WIDTH: f64 = 1e-9;

/// Deterministic min-priority event queue keyed on simulation time —
/// the timing-wheel implementation (see module docs for the layout and
/// the pop-order-identity argument).
pub struct EventQueue<E> {
    /// Min-heap over the *active span*: every entry whose bucket index
    /// (under the current era's `origin`/`width`) is below `cursor`.
    /// Its top is always the global minimum when the queue is
    /// non-empty, so `peek_next_time` never scans.
    front: BinaryHeap<Entry<E>>,
    /// Near-future wheel: bucket `i` holds entries with
    /// `floor((t - origin) / width) == i`, unsorted until activation.
    buckets: Vec<Vec<Entry<E>>>,
    /// Next bucket to activate; buckets below it are empty (drained
    /// into `front`). Only ever advances within an era.
    cursor: usize,
    /// Wheel window start (lower bound of bucket 0) for the current
    /// era. `NEG_INFINITY` until the first cascade anchors it.
    origin: f64,
    /// Bucket width for the current era, adapted at each cascade to
    /// ~2× the mean inter-event gap observed in the overflow level.
    width: f64,
    /// Far-future level: entries beyond the wheel window, unordered.
    overflow: Vec<Entry<E>>,
    len: usize,
    seq: u64,
    now: f64,
    telemetry: QueueTelemetry,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            front: BinaryHeap::new(),
            buckets: Vec::new(),
            cursor: 0,
            origin: f64::NEG_INFINITY,
            width: 1.0,
            overflow: Vec::new(),
            len: 0,
            seq: 0,
            now: 0.0,
            telemetry: QueueTelemetry::default(),
        }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Operation counters (pushes, pops, peak pending, cascades).
    pub fn telemetry(&self) -> QueueTelemetry {
        self.telemetry
    }

    /// Bucket index of `t` under the current era, as f64 so the
    /// unanchored (`-inf` origin ⇒ `+inf` index ⇒ overflow) and
    /// pre-window (`t < origin` ⇒ negative ⇒ active heap) cases fall
    /// out of the same comparison chain. Weakly monotone in `t` —
    /// subtraction, division by a positive width, and `floor` each
    /// preserve order under IEEE-754 rounding — which is what makes
    /// bucket order imply time order.
    #[inline]
    fn bucket_of(&self, t: f64) -> f64 {
        ((t - self.origin) / self.width).floor()
    }

    /// Schedule `event` at absolute time `t` (clamped to now — events in
    /// the past fire immediately-next). Panics on non-finite `t`: a
    /// NaN/inf timestamp has no place on the wheel (and would break the
    /// horizon guarantees even where `total_cmp` keeps the order total).
    pub fn push(&mut self, t: f64, event: E) {
        assert!(
            t.is_finite(),
            "EventQueue::push: non-finite event time {t} at sim time {} \
             (a NaN/inf timestamp would corrupt event ordering)",
            self.now
        );
        let t = if t < self.now { self.now } else { t };
        let entry = Entry { time: t, seq: self.seq, event };
        self.seq += 1;
        self.len += 1;
        self.telemetry.on_push(self.len);
        let idx = self.bucket_of(t);
        if idx < self.cursor as f64 {
            // At or before the active span: strictly earlier than every
            // bucketed entry (floor monotonicity), so it belongs in the
            // front heap, which orders it by (total_cmp time, seq).
            self.front.push(entry);
        } else if idx < self.buckets.len() as f64 {
            self.buckets[idx as usize].push(entry);
        } else {
            self.overflow.push(entry);
        }
        if self.front.is_empty() {
            // Keep the invariant "front non-empty whenever len > 0" so
            // peek_next_time stays O(1).
            self.refill_front();
        }
    }

    /// Schedule `event` after a delay.
    pub fn push_after(&mut self, delay: f64, event: E) {
        let now = self.now;
        self.push(now + delay.max(0.0), event);
    }

    /// Time of the earliest queued event without popping it — the
    /// *horizon* used by decode fast-forwarding: nothing can change the
    /// simulation state strictly before this time. O(1): the front
    /// heap's top is the cached global minimum.
    pub fn peek_next_time(&self) -> Option<f64> {
        self.front.peek().map(|e| e.time)
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let e = self.front.pop()?;
        debug_assert!(e.time >= self.now);
        self.now = e.time;
        self.len -= 1;
        self.telemetry.pops += 1;
        if self.front.is_empty() && self.len > 0 {
            self.refill_front();
        }
        Some((e.time, e.event))
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn len(&self) -> usize {
        self.len
    }

    /// Restore the front-heap invariant: activate the earliest
    /// non-empty bucket (heapify it in O(k)), or — when the wheel is
    /// exhausted — cascade the overflow level into a re-anchored wheel.
    fn refill_front(&mut self) {
        debug_assert!(self.front.is_empty());
        loop {
            while self.cursor < self.buckets.len() && self.buckets[self.cursor].is_empty() {
                self.cursor += 1;
            }
            if self.cursor < self.buckets.len() {
                let bucket = std::mem::take(&mut self.buckets[self.cursor]);
                self.cursor += 1;
                // In-place heapify reusing the bucket's allocation.
                self.front = BinaryHeap::from(bucket);
                return;
            }
            if self.overflow.is_empty() {
                debug_assert_eq!(self.len, 0);
                return;
            }
            self.cascade();
        }
    }

    /// Wheel rollover: re-anchor the window at the earliest overflow
    /// event and adapt bucket width (≈2× the mean inter-event gap) and
    /// bucket count (≈ the overflow population, clamped) to the
    /// observed spacing, then route every overflow entry that now falls
    /// inside the window down into its bucket. Only called with the
    /// front heap and every bucket empty, so re-anchoring cannot
    /// reorder anything: all remaining events are in the overflow
    /// level. Guaranteed progress: the minimum lands in bucket 0.
    fn cascade(&mut self) {
        debug_assert!(self.front.is_empty());
        debug_assert!(self.buckets.iter().all(|b| b.is_empty()));
        self.telemetry.overflow_cascades += 1;
        let mut min_t = f64::INFINITY;
        let mut max_t = f64::NEG_INFINITY;
        for e in &self.overflow {
            min_t = min_t.min(e.time);
            max_t = max_t.max(e.time);
        }
        let n = self.overflow.len();
        let mean_gap = (max_t - min_t) / n as f64;
        // Calendar-queue rule of thumb: ~2 events per bucket in the
        // uniform case; the whole overflow fits in one window whenever
        // it holds no more than 2× the bucket count.
        self.width = (2.0 * mean_gap).max(MIN_BUCKET_WIDTH);
        self.origin = min_t;
        self.cursor = 0;
        let want = n.next_power_of_two().clamp(MIN_BUCKETS, MAX_BUCKETS);
        if self.buckets.len() != want {
            self.buckets.resize_with(want, Vec::new);
        }
        let mut i = 0;
        while i < self.overflow.len() {
            let idx = self.bucket_of(self.overflow[i].time);
            debug_assert!(idx >= 0.0);
            if idx < self.buckets.len() as f64 {
                let e = self.overflow.swap_remove(i);
                self.buckets[idx as usize].push(e);
            } else {
                i += 1;
            }
        }
    }
}

/// The pre-timing-wheel implementation: one global `BinaryHeap` with
/// O(log n) push/pop, retained verbatim (modulo the `Entry` ordering
/// now being total by construction via `f64::total_cmp`) as the
/// **differential-testing oracle** for [`EventQueue`]. Same public
/// API, same clamping and non-finite panic, and — the contract
/// `rust/tests/event_queue_differential.rs` proves — the exact same
/// pop sequence for any schedule. Not used by the driver.
pub struct HeapQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: f64,
    telemetry: QueueTelemetry,
}

impl<E> Default for HeapQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> HeapQueue<E> {
    pub fn new() -> Self {
        HeapQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
            telemetry: QueueTelemetry::default(),
        }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Operation counters (`overflow_cascades` is always 0 here).
    pub fn telemetry(&self) -> QueueTelemetry {
        self.telemetry
    }

    /// Schedule `event` at absolute time `t` (clamped to now). Panics
    /// on non-finite `t`, mirroring [`EventQueue::push`].
    pub fn push(&mut self, t: f64, event: E) {
        assert!(
            t.is_finite(),
            "EventQueue::push: non-finite event time {t} at sim time {} \
             (a NaN/inf timestamp would corrupt event ordering)",
            self.now
        );
        let t = if t < self.now { self.now } else { t };
        self.heap.push(Entry { time: t, seq: self.seq, event });
        self.seq += 1;
        self.telemetry.on_push(self.heap.len());
    }

    /// Schedule `event` after a delay.
    pub fn push_after(&mut self, delay: f64, event: E) {
        let now = self.now;
        self.push(now + delay.max(0.0), event);
    }

    /// Time of the earliest queued event without popping it.
    pub fn peek_next_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let e = self.heap.pop()?;
        debug_assert!(e.time >= self.now);
        self.now = e.time;
        self.telemetry.pops += 1;
        Some((e.time, e.event))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.push(1.0, ());
        let (t1, _) = q.pop().unwrap();
        assert_eq!(q.now(), t1);
        // Pushing into the past clamps to now.
        q.push(0.0, ());
        let (t2, _) = q.pop().unwrap();
        assert!(t2 >= t1);
        let (t3, _) = q.pop().unwrap();
        assert_eq!(t3, 5.0);
    }

    #[test]
    fn peek_returns_earliest_without_popping() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_next_time(), None);
        q.push(4.0, "b");
        q.push(2.0, "a");
        assert_eq!(q.peek_next_time(), Some(2.0));
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.peek_next_time(), Some(4.0));
    }

    #[test]
    fn peek_sees_later_push_below_current_minimum() {
        // A push earlier than everything pending must surface through
        // peek immediately (it routes into the active heap).
        let mut q = EventQueue::new();
        q.push(100.0, "far");
        q.push(200.0, "farther");
        assert_eq!(q.peek_next_time(), Some(100.0));
        q.push(50.0, "near");
        assert_eq!(q.peek_next_time(), Some(50.0));
        assert_eq!(q.pop().unwrap().1, "near");
        assert_eq!(q.pop().unwrap().1, "far");
        assert_eq!(q.pop().unwrap().1, "farther");
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn nan_time_panics_instead_of_corrupting_heap() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn infinite_time_panics() {
        let mut q = EventQueue::new();
        q.push(f64::INFINITY, ());
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn heap_oracle_nan_time_panics_too() {
        let mut q = HeapQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    fn push_after_is_relative() {
        let mut q = EventQueue::new();
        q.push(2.0, "first");
        q.pop();
        q.push_after(3.0, "second");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 5.0);
    }

    #[test]
    fn far_future_outliers_cascade_and_pop_in_order() {
        // A near cluster plus outliers far beyond any initial window:
        // the outliers sit in the overflow level until the wheel rolls
        // over, then cascade down — order must be unaffected.
        let mut q = EventQueue::new();
        for i in 0..100u64 {
            q.push(i as f64 * 0.01, i);
        }
        q.push(1.0e6, 1000);
        q.push(2.0e6, 1001);
        q.push(1.5e6, 1002);
        let mut order = Vec::new();
        while let Some((_, e)) = q.pop() {
            order.push(e);
        }
        let mut expect: Vec<u64> = (0..100).collect();
        expect.extend([1000, 1002, 1001]);
        assert_eq!(order, expect);
        assert!(q.telemetry().overflow_cascades >= 1, "{:?}", q.telemetry());
    }

    #[test]
    fn telemetry_counts_ops_and_peak() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(i as f64, i);
        }
        q.pop();
        q.pop();
        q.push(100.0, 99);
        let t = q.telemetry();
        assert_eq!(t.pushes, 11);
        assert_eq!(t.pops, 2);
        assert_eq!(t.peak_pending, 10);
        assert_eq!(q.len(), 9);
        // The heap oracle exposes the same counters.
        let mut h: HeapQueue<u64> = HeapQueue::new();
        h.push(1.0, 1);
        h.push(2.0, 2);
        h.pop();
        let t = h.telemetry();
        assert_eq!((t.pushes, t.pops, t.peak_pending, t.overflow_cascades), (2, 1, 2, 0));
    }

    #[test]
    fn wheel_matches_heap_on_interleaved_churn() {
        // Quick in-module sanity check; the adversarial differential
        // suite lives in rust/tests/event_queue_differential.rs.
        let mut wheel = EventQueue::new();
        let mut heap = HeapQueue::new();
        let mut state = 0x9E37_79B9u64;
        let mut tick = 0.0f64;
        for i in 0..5_000u64 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let r = (state >> 33) as f64 / (1u64 << 31) as f64;
            match state % 7 {
                0 => tick += r * 3.0,
                1 => {
                    // Far-future outlier.
                    wheel.push(tick + 1e5 * (1.0 + r), i);
                    heap.push(tick + 1e5 * (1.0 + r), i);
                }
                2 | 3 => {
                    let (a, b) = (wheel.pop(), heap.pop());
                    assert_eq!(
                        a.as_ref().map(|(t, e)| (t.to_bits(), *e)),
                        b.as_ref().map(|(t, e)| (t.to_bits(), *e)),
                    );
                }
                _ => {
                    // Near push, sometimes an exact tie with `tick`.
                    let t = if state % 2 == 0 { tick } else { tick + r * 0.5 };
                    wheel.push(t, i);
                    heap.push(t, i);
                }
            }
            assert_eq!(wheel.peek_next_time(), heap.peek_next_time());
            assert_eq!(wheel.len(), heap.len());
        }
        loop {
            let (a, b) = (wheel.pop(), heap.pop());
            assert_eq!(
                a.as_ref().map(|(t, e)| (t.to_bits(), *e)),
                b.as_ref().map(|(t, e)| (t.to_bits(), *e)),
            );
            if a.is_none() {
                break;
            }
        }
    }
}
