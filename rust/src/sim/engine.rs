//! Discrete-event simulation core: a virtual clock and a deterministic
//! priority event queue. All serving systems (ElasticMM and the
//! baselines) run on this engine so their comparison is apples-to-apples.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Entry in the event queue. Ordered by (time, seq) so simultaneous
/// events pop in insertion order — determinism matters for reproducible
/// experiments.
struct Entry<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap behaviour on BinaryHeap (a max-heap).
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic min-priority event queue keyed on simulation time.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0 }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `event` at absolute time `t` (clamped to now — events in
    /// the past fire immediately-next). Panics on non-finite `t`:
    /// `Entry::cmp` falls back to `Ordering::Equal` for incomparable
    /// times, so a single NaN would silently corrupt heap ordering.
    pub fn push(&mut self, t: f64, event: E) {
        assert!(
            t.is_finite(),
            "EventQueue::push: non-finite event time {t} at sim time {} \
             (a NaN/inf timestamp would corrupt heap ordering)",
            self.now
        );
        let t = if t < self.now { self.now } else { t };
        self.heap.push(Entry { time: t, seq: self.seq, event });
        self.seq += 1;
    }

    /// Schedule `event` after a delay.
    pub fn push_after(&mut self, delay: f64, event: E) {
        let now = self.now;
        self.push(now + delay.max(0.0), event);
    }

    /// Time of the earliest queued event without popping it — the
    /// *horizon* used by decode fast-forwarding: nothing can change the
    /// simulation state strictly before this time.
    pub fn peek_next_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let e = self.heap.pop()?;
        debug_assert!(e.time >= self.now);
        self.now = e.time;
        Some((e.time, e.event))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.push(1.0, ());
        let (t1, _) = q.pop().unwrap();
        assert_eq!(q.now(), t1);
        // Pushing into the past clamps to now.
        q.push(0.0, ());
        let (t2, _) = q.pop().unwrap();
        assert!(t2 >= t1);
        let (t3, _) = q.pop().unwrap();
        assert_eq!(t3, 5.0);
    }

    #[test]
    fn peek_returns_earliest_without_popping() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_next_time(), None);
        q.push(4.0, "b");
        q.push(2.0, "a");
        assert_eq!(q.peek_next_time(), Some(2.0));
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.peek_next_time(), Some(4.0));
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn nan_time_panics_instead_of_corrupting_heap() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn infinite_time_panics() {
        let mut q = EventQueue::new();
        q.push(f64::INFINITY, ());
    }

    #[test]
    fn push_after_is_relative() {
        let mut q = EventQueue::new();
        q.push(2.0, "first");
        q.pop();
        q.push_after(3.0, "second");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 5.0);
    }
}
