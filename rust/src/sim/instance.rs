//! Simulated elastic instances and per-request lifecycle state shared by
//! every serving system (ElasticMM and baselines).
//!
//! An *elastic instance* (paper Fig 2/3) is the schedulable unit: one
//! model replica on `tp` GPUs. Within a stage the paper prioritizes data
//! parallelism — each instance starts at `CostModel::min_tp()` GPUs —
//! but the TP dimension is elastic too (Elastic Partition Scheduling
//! "enables parallelism adjustment"): under `SchedulerConfig::max_tp >
//! min_tp` the coordinator may *merge* drained prefill instances into a
//! wider TP group (the absorbed instance slot lends its GPU set to the
//! leader and disappears from scheduling) and later *split* them back.
//! Each instance therefore owns an explicit GPU set; the invariant that
//! every GPU belongs to exactly one live TP group at all times is
//! checked by [`check_gpu_partition`].

use crate::kvcache::paged::PagedKvCache;
use crate::model::{CostModel, DecodeItem};
use crate::sim::slab::{ReqIx, RequestSlab};
use crate::workload::{EncodeJob, Request};

/// Which inference stage an instance currently serves (stage-level
/// disaggregation, §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageRole {
    /// Media encoder replica.
    Encode,
    /// LLM prefill replica.
    Prefill,
    /// LLM decode replica.
    Decode,
    /// Coupled baseline: everything on one replica.
    Unified,
}

/// Which modality group owns an instance (modality-level separation,
/// §3). An index into the owning system's modality-group registry —
/// which modality a group serves is the system's configuration
/// (`EmpOptions::groups`), not a property of the id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GroupId(pub u8);

impl GroupId {
    pub const fn index(self) -> usize {
        self.0 as usize
    }
}

/// A simulated elastic instance.
#[derive(Debug)]
pub struct Instance {
    pub id: usize,
    /// Tensor-parallel degree == `gpus.len()` while live, 0 while
    /// absorbed into another instance's TP group.
    pub tp: usize,
    pub role: StageRole,
    pub group: GroupId,
    /// GPU ids this instance's TP group owns. Empty while the slot is
    /// absorbed (its GPUs moved to the absorbing leader).
    pub gpus: Vec<usize>,
    /// Instance slots this leader has absorbed, in merge order, as
    /// `(instance id, gpu count it brought)`. A split pops the most
    /// recent entry and hands back exactly the tail of `gpus` — merges
    /// and splits are symmetric by construction.
    pub absorbed: Vec<(usize, usize)>,
    /// Busy with the current iteration until this sim time.
    pub busy_until: f64,
    /// Sequences currently resident for decode (slab indices into the
    /// owning system's [`RequestSlab`]).
    pub decoding: Vec<ReqIx>,
    /// Paged KV pool (token-granular accounting, Appendix A).
    pub kv: PagedKvCache,
    /// Tokens decoded on this instance (utilization accounting).
    pub tokens_processed: u64,
    /// Total busy seconds (utilization accounting). Excludes TP
    /// re-shard delays — those GPUs serve nothing.
    pub busy_time: f64,
}

impl Instance {
    /// Instances are constructed back to back at system start, each
    /// spanning `tp` contiguous GPUs — so instance `i` owns GPUs
    /// `i*tp .. (i+1)*tp`, and together they partition the cluster.
    pub fn new(id: usize, tp: usize, role: StageRole, group: GroupId, kv_tokens: usize) -> Self {
        Instance {
            id,
            tp,
            role,
            group,
            gpus: (id * tp..(id + 1) * tp).collect(),
            absorbed: Vec::new(),
            busy_until: 0.0,
            decoding: Vec::new(),
            kv: PagedKvCache::new(kv_tokens, 16),
            tokens_processed: 0,
            busy_time: 0.0,
        }
    }

    /// Whether this slot heads a live TP group (false while absorbed
    /// into another instance — then it owns no GPUs and must not be
    /// scheduled).
    pub fn live(&self) -> bool {
        !self.gpus.is_empty()
    }

    pub fn idle_at(&self, now: f64) -> bool {
        self.busy_until <= now
    }

    /// Begin an iteration of `duration`; returns its completion time.
    pub fn start_iteration(&mut self, now: f64, duration: f64) -> f64 {
        debug_assert!(self.idle_at(now), "instance {} double-booked", self.id);
        self.busy_until = now + duration;
        self.busy_time += duration;
        self.busy_until
    }

    pub fn kv_free_tokens(&self) -> usize {
        self.kv.free_tokens()
    }
}

/// Cross-instance consistency shared by every serving system: each
/// instance's KV pool is internally consistent and every resident
/// decoding id maps to a request homed on that instance. Systems call
/// this from `ServingSystem::verify_invariants` and layer their own
/// checks on top.
pub fn check_instances(
    instances: &[Instance],
    requests: &RequestSlab,
) -> Result<(), String> {
    for inst in instances {
        inst.kv.check_invariants()?;
        if !inst.live() {
            // Absorbed slots lent their GPUs away drained: they may
            // hold no sequences, reservations, or in-flight work.
            if !inst.decoding.is_empty() || inst.kv.num_seqs() != 0 {
                return Err(format!(
                    "absorbed instance {} still holds sequences ({} decoding, {} in kv)",
                    inst.id,
                    inst.decoding.len(),
                    inst.kv.num_seqs()
                ));
            }
            continue;
        }
        for &ix in &inst.decoding {
            let r = requests
                .try_get(ix)
                .ok_or_else(|| format!("decoding unknown request slot {ix}"))?;
            if r.home != Some(inst.id) {
                return Err(format!("request {} home mismatch", r.req.id));
            }
        }
    }
    Ok(())
}

/// GPU-set ownership invariant for elastic TP: every GPU of the cluster
/// belongs to exactly one *live* TP group — live instances' GPU sets
/// are disjoint, sized `tp`, and together cover exactly the
/// `expected_gpus` handed out at construction; absorbed slots own
/// nothing and carry `tp == 0`.
pub fn check_gpu_partition(instances: &[Instance], expected_gpus: usize) -> Result<(), String> {
    let mut seen = std::collections::BTreeSet::new();
    for inst in instances {
        if !inst.live() {
            if inst.tp != 0 {
                return Err(format!(
                    "absorbed instance {} has tp={} but owns no GPUs",
                    inst.id, inst.tp
                ));
            }
            continue;
        }
        if inst.tp != inst.gpus.len() {
            return Err(format!(
                "instance {} tp={} but owns {} GPUs",
                inst.id,
                inst.tp,
                inst.gpus.len()
            ));
        }
        for &g in &inst.gpus {
            if !seen.insert(g) {
                return Err(format!("GPU {g} owned by more than one live TP group"));
            }
        }
    }
    if seen.len() != expected_gpus {
        return Err(format!(
            "live TP groups cover {} of {expected_gpus} GPUs",
            seen.len()
        ));
    }
    Ok(())
}

/// Total KV tokens currently allocated across `instances` (must be
/// zero once a run completes).
pub fn kv_tokens_in_use(instances: &[Instance]) -> usize {
    instances.iter().map(|i| i.kv.used_tokens()).sum()
}

/// Cost of one decode step over `ids`, building the `DecodeItem` batch
/// into the caller's reusable `scratch` buffer (cleared here; no
/// per-step allocation). Shared by every serving system so batch-cost
/// construction cannot drift between them.
pub fn decode_batch_time(
    cost: &CostModel,
    requests: &RequestSlab,
    tp: usize,
    ids: &[ReqIx],
    scratch: &mut Vec<DecodeItem>,
    cross_attn: bool,
) -> f64 {
    scratch.clear();
    for &ix in ids {
        let r = requests.get(ix);
        scratch.push(DecodeItem { context_len: r.context_len(), vision_tokens: r.vision_tokens });
    }
    cost.decode_step_time_flags(scratch, tp, cross_attn)
}

/// Shared core of decode fast-forwarding, used by every serving system:
/// commit as many consecutive decode steps of `ids` as end strictly
/// before `horizon` and complete no request, then account the
/// *boundary* step (the one that crosses the horizon or finishes a
/// sequence) exactly as `start_iteration` would. Returns the committed
/// step count and the boundary step's completion time; the caller
/// records its in-flight iteration and pushes the completion event.
///
/// All bit-exactness-critical float accumulation lives here and in
/// [`CostModel::decode_run_time_flags`] — systems must not reimplement
/// it, or the fast/step-by-step report equivalence can drift.
/// `scratch` is a reusable `DecodeItem` buffer (cleared here).
#[allow(clippy::too_many_arguments)]
pub fn fast_forward_decode_batch(
    cost: &CostModel,
    requests: &mut RequestSlab,
    inst: &mut Instance,
    ids: &[ReqIx],
    scratch: &mut Vec<DecodeItem>,
    cross_attn: bool,
    now: f64,
    horizon: Option<f64>,
) -> (usize, f64) {
    scratch.clear();
    // Steps until the first in-batch completion: the completing step
    // must run as a real event (it changes the batch and triggers
    // completion-side policy).
    let mut max_steps = usize::MAX;
    for &ix in ids {
        let r = requests.get(ix);
        scratch.push(DecodeItem { context_len: r.context_len(), vision_tokens: r.vision_tokens });
        max_steps = max_steps.min(r.req.output_tokens - r.decoded - 1);
    }
    let tp = inst.tp;
    let (steps, start) = cost.decode_run_time_flags(
        scratch,
        tp,
        cross_attn,
        max_steps,
        now,
        horizon,
        &mut inst.busy_time,
    );
    if steps > 0 {
        for &ix in ids {
            requests.get_mut(ix).decoded += steps;
        }
        inst.tokens_processed += (steps * ids.len()) as u64;
    }
    // Boundary step, scheduled exactly as a fresh decode dispatch would
    // start it at `start` with the advanced context lengths.
    let dur = cost.decode_step_time_flags(scratch, tp, cross_attn);
    let done = start + dur;
    inst.busy_until = done;
    inst.busy_time += dur;
    (steps, done)
}

/// Request lifecycle phase in the simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Waiting for preprocessing/encoding capacity (multimodal only).
    WaitEncode,
    /// Image encoding in flight.
    Encoding,
    /// Encoded (or text-only); waiting for prefill admission.
    WaitPrefill,
    /// Prefill in flight (possibly chunked across iterations).
    Prefilling,
    /// KV migrating between instances (paused).
    Migrating,
    /// Generating tokens.
    Decoding,
    Finished,
}

impl Phase {
    /// All phases in declaration (= pipeline) order; the single source
    /// of truth for [`Phase::COUNT`] and [`Phase::index`].
    pub const ALL: [Phase; 7] = [
        Phase::WaitEncode,
        Phase::Encoding,
        Phase::WaitPrefill,
        Phase::Prefilling,
        Phase::Migrating,
        Phase::Decoding,
        Phase::Finished,
    ];
    pub const COUNT: usize = Phase::ALL.len();

    /// Dense index: the discriminant, which matches the position in
    /// [`Phase::ALL`] because both follow declaration order.
    pub fn index(&self) -> usize {
        *self as usize
    }

    pub fn name(&self) -> &'static str {
        match self {
            Phase::WaitEncode => "WaitEncode",
            Phase::Encoding => "Encoding",
            Phase::WaitPrefill => "WaitPrefill",
            Phase::Prefilling => "Prefilling",
            Phase::Migrating => "Migrating",
            Phase::Decoding => "Decoding",
            Phase::Finished => "Finished",
        }
    }
}

/// Per-request simulation state + timing record.
#[derive(Debug, Clone)]
pub struct SimRequest {
    pub req: Request,
    pub phase: Phase,
    /// Media tokens (vision + audio) for the chosen model.
    pub vision_tokens: usize,
    /// Full input context (prompt + media tokens).
    pub input_len: usize,
    /// Encoder work units still pending (after media-cache hits); a
    /// video clip is several jobs — one per chunk — so a long clip's
    /// later chunks can encode while its earlier tokens already
    /// prefill. Jobs are consumed back-to-front (`pop`).
    pub encode_pending: Vec<EncodeJob>,
    /// When true the pending encode work is charged *inline* inside the
    /// prefill iteration (blocking-encode mode / fallback) instead of on
    /// the encoder pool; every pending token then counts as prefillable.
    pub inline_encode: bool,
    /// Set at prefill dispatch when the in-flight iteration's duration
    /// actually charged the pending encode jobs inline; consumed at
    /// iteration completion to clear `encode_pending`. Guards against
    /// `inline_encode` flipping on *mid-iteration* (the drain-stuck
    /// fallback): jobs are only discarded once an iteration has paid
    /// for them.
    pub encode_charged_inline: bool,
    /// Whether this request is currently queued in its group's
    /// `wait_prefill` (guards against double-enqueue while encode chunks
    /// and partial prefills interleave).
    pub in_wait_prefill: bool,
    /// Tokens admitted to the in-flight prefill iteration (consumed at
    /// iteration completion; a request is in at most one prefill
    /// iteration at a time).
    pub prefill_inflight: usize,
    /// Prefill tokens skipped via unified prefix cache.
    pub cached_prefix: usize,
    /// Prefill tokens completed so far (excluding cached prefix).
    pub prefill_done: usize,
    /// Prefill tokens required (input_len - cached_prefix).
    pub prefill_target: usize,
    /// Output tokens generated so far.
    pub decoded: usize,
    /// Instance currently holding this request's KV (decode home).
    pub home: Option<usize>,
    // --- timing record -------------------------------------------------
    pub t_arrival: f64,
    pub t_encode_done: f64,
    pub t_first_token: f64,
    pub t_finish: f64,
}

impl SimRequest {
    pub fn new(req: Request, media_tokens: usize) -> Self {
        let input_len = req.prompt_tokens + media_tokens;
        let phase = if media_tokens > 0 { Phase::WaitEncode } else { Phase::WaitPrefill };
        let t_arrival = req.arrival;
        SimRequest {
            req,
            phase,
            vision_tokens: media_tokens,
            input_len,
            encode_pending: Vec::new(),
            inline_encode: false,
            encode_charged_inline: false,
            in_wait_prefill: false,
            prefill_inflight: 0,
            cached_prefix: 0,
            prefill_done: 0,
            prefill_target: input_len,
            decoded: 0,
            home: None,
            t_arrival,
            t_encode_done: f64::NAN,
            t_first_token: f64::NAN,
            t_finish: f64::NAN,
        }
    }

    pub fn prefill_remaining(&self) -> usize {
        self.prefill_target.saturating_sub(self.prefill_done)
    }

    /// Media tokens whose encode jobs have not run yet.
    pub fn pending_media_tokens(&self) -> usize {
        self.encode_pending.iter().map(|j| j.tokens).sum()
    }

    /// Prefill tokens admissible *right now*: everything not yet
    /// prefilled except media tokens still waiting on the encoder pool.
    /// Inline-encode requests pay encoding inside the prefill iteration,
    /// so all remaining tokens are admissible.
    pub fn prefill_admissible(&self) -> usize {
        if self.inline_encode {
            self.prefill_remaining()
        } else {
            self.prefill_remaining().saturating_sub(self.pending_media_tokens())
        }
    }

    /// Context length while decoding (input + generated so far).
    pub fn context_len(&self) -> usize {
        self.input_len + self.decoded
    }

    pub fn finished(&self) -> bool {
        self.phase == Phase::Finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{MediaClass, MediaRef};

    fn request(images: usize) -> Request {
        Request {
            id: 1,
            arrival: 2.5,
            prompt_tokens: 100,
            output_tokens: 20,
            media: (0..images)
                .map(|i| MediaRef::image(448, 448, i as u64))
                .collect::<Vec<_>>()
                .into(),
            prefix_id: 0,
            prefix_tokens: 0,
        }
    }

    #[test]
    fn text_request_skips_encode_phase() {
        let r = SimRequest::new(request(0), 0);
        assert_eq!(r.phase, Phase::WaitPrefill);
        assert_eq!(r.input_len, 100);
    }

    #[test]
    fn multimodal_request_starts_in_encode() {
        let r = SimRequest::new(request(1), 1000);
        assert_eq!(r.phase, Phase::WaitEncode);
        assert_eq!(r.input_len, 1100);
        assert_eq!(r.t_arrival, 2.5);
    }

    #[test]
    fn prefill_remaining_accounts_progress() {
        let mut r = SimRequest::new(request(0), 0);
        r.cached_prefix = 30;
        r.prefill_target = 70;
        r.prefill_done = 50;
        assert_eq!(r.prefill_remaining(), 20);
        r.prefill_done = 70;
        assert_eq!(r.prefill_remaining(), 0);
    }

    #[test]
    fn prefill_admissible_excludes_pending_chunks() {
        let mut r = SimRequest::new(request(0), 2000);
        // 100 text + 2000 media tokens; 1200 of the media not yet encoded.
        r.encode_pending = vec![
            EncodeJob { class: MediaClass::Video, tokens: 800, frame_tokens: 400, tiles: 2 },
            EncodeJob { class: MediaClass::Video, tokens: 400, frame_tokens: 400, tiles: 1 },
        ];
        assert_eq!(r.pending_media_tokens(), 1200);
        assert_eq!(r.prefill_admissible(), 2100 - 1200);
        r.prefill_done = 500;
        assert_eq!(r.prefill_admissible(), 2100 - 500 - 1200);
        // Inline mode charges encode in the prefill iteration: all
        // remaining tokens admissible.
        r.inline_encode = true;
        assert_eq!(r.prefill_admissible(), r.prefill_remaining());
    }

    #[test]
    fn instance_iteration_accounting() {
        let mut inst = Instance::new(0, 1, StageRole::Unified, GroupId(0), 1600);
        assert!(inst.idle_at(0.0));
        let done = inst.start_iteration(1.0, 0.5);
        assert_eq!(done, 1.5);
        assert!(!inst.idle_at(1.2));
        assert!(inst.idle_at(1.5));
        assert!((inst.busy_time - 0.5).abs() < 1e-12);
    }

    #[test]
    fn context_len_grows_with_decode() {
        let mut r = SimRequest::new(request(0), 0);
        r.decoded = 7;
        assert_eq!(r.context_len(), 107);
    }

    #[test]
    fn instances_own_contiguous_gpu_sets() {
        let a = Instance::new(0, 2, StageRole::Prefill, GroupId(0), 1600);
        let b = Instance::new(1, 2, StageRole::Prefill, GroupId(0), 1600);
        assert_eq!(a.gpus, vec![0, 1]);
        assert_eq!(b.gpus, vec![2, 3]);
        assert!(a.live() && b.live());
        check_gpu_partition(&[a, b], 4).unwrap();
    }

    #[test]
    fn gpu_partition_detects_duplicates_gaps_and_stale_absorbed() {
        let mk = |id, tp| Instance::new(id, tp, StageRole::Prefill, GroupId(0), 1600);
        // A merge: instance 0 takes instance 1's GPU.
        let mut leader = mk(0, 1);
        let mut other = mk(1, 1);
        leader.gpus.extend(other.gpus.drain(..));
        leader.tp = 2;
        leader.absorbed.push((1, 1));
        other.tp = 0;
        check_gpu_partition(&[leader, other], 2).unwrap();
        // Duplicate ownership.
        let dup = [mk(0, 1), mk(0, 1)];
        assert!(check_gpu_partition(&dup, 2).is_err());
        // Coverage gap (a GPU vanished).
        assert!(check_gpu_partition(&[mk(0, 1)], 2).is_err());
        // tp out of sync with the owned set.
        let mut bad = mk(0, 1);
        bad.tp = 2;
        assert!(check_gpu_partition(&[bad], 1).is_err());
        // Absorbed slot that kept a stale tp.
        let mut stale = mk(1, 1);
        stale.gpus.clear();
        stale.tp = 1;
        let full = mk(0, 1);
        assert!(check_gpu_partition(&[full, stale], 1).is_err());
    }

    #[test]
    fn absorbed_instances_must_be_drained() {
        let requests = RequestSlab::new();
        let mut inst = Instance::new(0, 1, StageRole::Prefill, GroupId(0), 1600);
        inst.gpus.clear();
        inst.tp = 0;
        inst.kv.allocate(7, 64).unwrap();
        assert!(check_instances(&[inst], &requests).is_err());
    }
}
