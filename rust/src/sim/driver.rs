//! The shared trace driver every serving system runs on.
//!
//! Historically `EmpSystem`, `CoupledVllm`, and `DecoupledStatic` each
//! hand-rolled a near-identical discrete-event loop (arrival injection,
//! pop-dispatch, stall detection, report collection). That duplication is
//! now owned once, here: a system implements [`ServingSystem`] —
//! `route` new requests, `on_event` its own events, optionally a periodic
//! tick — and [`run_trace`] drives it to completion. Benchmarks compare
//! systems through this one driver, so the comparison is apples-to-apples
//! by construction, and a new baseline or scheduling policy is one
//! trait impl away.
//!
//! Arrivals are injected *lazily*: only the next pending arrival sits in
//! the queue at any time. Besides keeping the heap small, this lets the
//! driver tell systems the time of the next **external** event (next
//! arrival or periodic tick) via [`SimQueue::next_external_time`] — the
//! coalescing horizon used by decode fast-forwarding in systems whose
//! instances are independent between arrivals (the coupled baselines).
//! [`SimQueue::peek_next_time`] exposes the global horizon (earliest
//! event of any kind) for systems with cross-instance coupling.

use crate::metrics::{Report, RequestRecord};
use crate::sim::engine::EventQueue;
use crate::workload::Request;

/// Driver-level event wrapper. Arrival injection and periodic ticks are
/// owned by the driver; `Sys` carries a system-specific event.
enum DriverEv<E> {
    Arrive(usize),
    Tick,
    Sys(E),
}

/// Times of the next driver-owned (external) events, snapshotted for the
/// duration of one event dispatch. `None` = no such event pending.
#[derive(Debug, Clone, Copy, Default)]
struct ExternalTimes {
    arrival: Option<f64>,
    tick: Option<f64>,
}

impl ExternalTimes {
    fn min(&self) -> Option<f64> {
        match (self.arrival, self.tick) {
            (Some(a), Some(t)) => Some(a.min(t)),
            (a, t) => a.or(t),
        }
    }
}

/// The system-facing view of the event queue: systems read the clock and
/// schedule their own events, while arrival and tick bookkeeping stay
/// with the driver.
pub struct SimQueue<'a, E> {
    inner: &'a mut EventQueue<DriverEv<E>>,
    ext: ExternalTimes,
}

impl<'a, E> SimQueue<'a, E> {
    /// Current simulation time.
    pub fn now(&self) -> f64 {
        self.inner.now()
    }

    /// Schedule a system event at absolute time `t`.
    pub fn push(&mut self, t: f64, ev: E) {
        self.inner.push(t, DriverEv::Sys(ev));
    }

    /// Schedule a system event after a delay.
    pub fn push_after(&mut self, delay: f64, ev: E) {
        self.inner.push_after(delay, DriverEv::Sys(ev));
    }

    /// Global coalescing horizon: the time of the earliest queued event
    /// of *any* kind. Nothing in the simulation can change strictly
    /// before this time, so a decode batch whose every step completes
    /// strictly earlier can be fast-forwarded without observing or
    /// perturbing anything. `None` = the queue is empty.
    pub fn peek_next_time(&self) -> Option<f64> {
        self.inner.peek_next_time()
    }

    /// External coalescing horizon: the earliest *driver-owned* event
    /// (next trace arrival or periodic tick). Valid as a fast-forward
    /// horizon only for systems whose event handlers never read or
    /// mutate another instance's decode state — then instance-local
    /// decode runs may safely overlap other instances' iteration
    /// boundaries, and only arrivals/ticks can perturb them. `None` =
    /// no arrivals left and no tick armed.
    pub fn next_external_time(&self) -> Option<f64> {
        self.ext.min()
    }
}

/// Counters from one [`run_trace_with_stats`] run — the denominator for
/// the `sim-events/sec` metric in `benches/sim_throughput.rs`.
#[derive(Debug, Clone, Copy, Default)]
pub struct DriverStats {
    /// Total events dispatched (arrivals + ticks + system events).
    pub events: u64,
    pub arrivals: u64,
    pub ticks: u64,
    pub sys_events: u64,
}

/// A serving system that can be driven over a request trace by
/// [`run_trace`]. Implementations own *policy* (what to do with a
/// request or event); the driver owns *mechanism* (the event loop).
pub trait ServingSystem {
    /// System-specific event type (iteration completions, migrations...).
    type Ev;

    /// Handle a newly arrived request (the driver injects arrivals from
    /// the trace at their `arrival` timestamps).
    fn route(&mut self, req: Request, q: &mut SimQueue<'_, Self::Ev>);

    /// Handle a system-specific event previously pushed onto `q`.
    fn on_event(&mut self, ev: Self::Ev, q: &mut SimQueue<'_, Self::Ev>);

    /// Interval of the periodic driver tick, if the system wants one
    /// (e.g. EMP's proactive rebalance, §3.1). The driver re-arms the
    /// tick until the run completes.
    fn tick_interval(&self) -> Option<f64> {
        None
    }

    /// Periodic tick handler (only called when [`Self::tick_interval`]
    /// returns `Some`).
    fn on_tick(&mut self, _q: &mut SimQueue<'_, Self::Ev>) {}

    /// Number of requests completed so far (drives [`Self::is_done`] and
    /// the stall diagnostic).
    fn completed(&self) -> usize;

    /// Whether the run is finished for a trace of `total` requests.
    fn is_done(&self, total: usize) -> bool {
        self.completed() >= total
    }

    /// Drain the completed-request records accumulated during the run.
    fn drain_records(&mut self) -> Vec<RequestRecord>;

    /// Cross-instance consistency checks (used by tests). Required so
    /// new systems cannot silently opt out of the driver contract.
    fn verify_invariants(&self) -> Result<(), String>;

    /// KV-cache tokens currently allocated across all instances. Must
    /// be zero after a completed run (`tests/driver_contract.rs`
    /// asserts this uniformly). Required — a `0` default would make
    /// the leak check vacuous for systems that forget to implement it.
    fn kv_in_use(&self) -> usize;

    /// Outstanding (not yet finished) requests bucketed by lifecycle
    /// phase, included in the driver's stall diagnostic so a policy bug
    /// is localizable from the panic message alone. Systems backed by a
    /// `RequestSlab` implement this via `RequestSlab::phase_histogram`;
    /// the default reports nothing.
    fn outstanding_by_phase(&self) -> Vec<(&'static str, usize)> {
        Vec::new()
    }

    /// Attach system-specific summary stats to the finished report
    /// (e.g. `EmpSystem` copies its elastic-TP reconfiguration counters
    /// into `Report::tp_reconfigs` / `tp_busy_gpu_seconds` /
    /// `tp_timeline`). Called once by the driver after the run
    /// completes; the default attaches nothing.
    fn annotate_report(&self, _rep: &mut Report) {}

    /// Run a trace to completion through the shared driver.
    fn run(&mut self, trace: &[Request]) -> Report
    where
        Self: Sized,
    {
        run_trace(self, trace)
    }
}

fn stall_message<S: ServingSystem + ?Sized>(sys: &S, total: usize, detail: &str) -> String {
    let mut msg = format!(
        "simulation stalled: {}/{} requests finished{detail}",
        sys.completed(),
        total
    );
    let hist = sys.outstanding_by_phase();
    if hist.is_empty() {
        msg.push_str(" (no phase breakdown available)");
    } else {
        msg.push_str("; outstanding by phase:");
        for (name, count) in hist {
            msg.push_str(&format!(" {name}={count}"));
        }
    }
    msg
}

/// [`run_trace`] plus the dispatch counters (see [`DriverStats`]).
pub fn run_trace_with_stats<S: ServingSystem + ?Sized>(
    sys: &mut S,
    trace: &[Request],
) -> (Report, DriverStats) {
    // Consecutive ticks with an otherwise-empty queue and no completion
    // progress before we declare a stall. One idle tick is legitimate
    // (e.g. a role-flip cooldown can defer work to the next tick);
    // several in a row mean no event will ever fire again.
    const MAX_IDLE_TICKS: u32 = 3;
    let total = trace.len();
    let mut q: EventQueue<DriverEv<S::Ev>> = EventQueue::new();
    // Lazy arrival injection: requests enter the queue one at a time in
    // arrival order (stable by trace index for identical timestamps, so
    // replays match the eager-injection behaviour).
    let mut order: Vec<usize> = (0..total).collect();
    order.sort_by(|&a, &b| trace[a].arrival.total_cmp(&trace[b].arrival));
    let mut next_arrival = 0usize;
    let mut ext = ExternalTimes::default();
    if let Some(&i) = order.first() {
        q.push(trace[i].arrival, DriverEv::Arrive(i));
        ext.arrival = Some(trace[i].arrival);
        next_arrival = 1;
    }
    if let Some(dt) = sys.tick_interval() {
        q.push(dt, DriverEv::Tick);
        ext.tick = Some(dt);
    }
    let mut stats = DriverStats::default();
    let mut idle_ticks = 0u32;
    while !sys.is_done(total) {
        let Some((_, ev)) = q.pop() else {
            panic!("{}", stall_message(sys, total, ""));
        };
        stats.events += 1;
        match ev {
            DriverEv::Arrive(i) => {
                stats.arrivals += 1;
                idle_ticks = 0;
                // Queue the next arrival *before* routing so every
                // handler sees a complete horizon.
                if let Some(&j) = order.get(next_arrival) {
                    q.push(trace[j].arrival, DriverEv::Arrive(j));
                    ext.arrival = Some(trace[j].arrival.max(q.now()));
                    next_arrival += 1;
                } else {
                    ext.arrival = None;
                }
                sys.route(trace[i].clone(), &mut SimQueue { inner: &mut q, ext });
            }
            DriverEv::Sys(e) => {
                stats.sys_events += 1;
                idle_ticks = 0;
                sys.on_event(e, &mut SimQueue { inner: &mut q, ext });
            }
            DriverEv::Tick => {
                stats.ticks += 1;
                let before = sys.completed();
                // Re-arm *before* the handler so the next tick is in the
                // queue (and in `ext`) while `on_tick` runs — both
                // coalescing horizons must stay truthful for any system
                // that reads them from a tick path. A stale tick left
                // behind by a run that completes inside `on_tick` is
                // harmless: the loop exits on `is_done`.
                let rearmed = match sys.tick_interval() {
                    Some(dt) if !sys.is_done(total) => {
                        let t = q.now() + dt.max(0.0);
                        q.push(t, DriverEv::Tick);
                        ext.tick = Some(t);
                        true
                    }
                    _ => {
                        ext.tick = None;
                        false
                    }
                };
                sys.on_tick(&mut SimQueue { inner: &mut q, ext });
                if rearmed {
                    // A tick-driven system keeps the queue nonempty
                    // forever via re-arming, so the empty-queue stall
                    // check above never fires for it: detect no-progress
                    // idle ticks instead (only the re-armed tick queued,
                    // no pending arrival, no completions).
                    if q.len() == 1 && ext.arrival.is_none() && sys.completed() == before {
                        idle_ticks += 1;
                        if idle_ticks >= MAX_IDLE_TICKS {
                            panic!(
                                "{}",
                                stall_message(
                                    sys,
                                    total,
                                    &format!(" ({idle_ticks} consecutive idle ticks)")
                                )
                            );
                        }
                    } else {
                        idle_ticks = 0;
                    }
                }
            }
        }
    }
    let mut report = Report::new(sys.drain_records());
    sys.annotate_report(&mut report);
    (report, stats)
}

/// The generic discrete-event loop: inject arrivals, arm the periodic
/// tick, dispatch events until every request finished, and collect the
/// [`Report`]. Panics with a stall diagnostic (including a per-phase
/// histogram of outstanding requests) if the event queue drains while
/// requests are still outstanding — a scheduling-policy bug, never a
/// workload property.
pub fn run_trace<S: ServingSystem + ?Sized>(sys: &mut S, trace: &[Request]) -> Report {
    run_trace_with_stats(sys, trace).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Request;

    fn req(id: u64, arrival: f64) -> Request {
        Request {
            id,
            arrival,
            prompt_tokens: 10,
            output_tokens: 2,
            media: Vec::new().into(),
            prefix_id: 0,
            prefix_tokens: 0,
        }
    }

    /// A single-server FIFO toy system: each request takes 1s of service.
    struct Fifo {
        busy_until: f64,
        finished: Vec<RequestRecord>,
        ticks: usize,
        drop_all: bool,
        tick_every: Option<f64>,
        outstanding: usize,
    }

    impl Fifo {
        fn new() -> Fifo {
            Fifo {
                busy_until: 0.0,
                finished: Vec::new(),
                ticks: 0,
                drop_all: false,
                tick_every: None,
                outstanding: 0,
            }
        }
    }

    enum FifoEv {
        Done(RequestRecord),
    }

    impl ServingSystem for Fifo {
        type Ev = FifoEv;

        fn route(&mut self, req: Request, q: &mut SimQueue<'_, FifoEv>) {
            if self.drop_all {
                self.outstanding += 1;
                return; // simulate a lost request → stall
            }
            let start = self.busy_until.max(q.now());
            let finish = start + 1.0;
            self.busy_until = finish;
            let rec = RequestRecord {
                id: req.id,
                modality: crate::workload::Modality::Text,
                input_len: req.prompt_tokens,
                output_len: req.output_tokens,
                arrival: req.arrival,
                first_token: start,
                finish,
            };
            q.push(finish, FifoEv::Done(rec));
        }

        fn on_event(&mut self, ev: FifoEv, _q: &mut SimQueue<'_, FifoEv>) {
            let FifoEv::Done(rec) = ev;
            self.finished.push(rec);
        }

        fn tick_interval(&self) -> Option<f64> {
            self.tick_every
        }

        fn on_tick(&mut self, _q: &mut SimQueue<'_, FifoEv>) {
            self.ticks += 1;
        }

        fn completed(&self) -> usize {
            self.finished.len()
        }

        fn drain_records(&mut self) -> Vec<RequestRecord> {
            std::mem::take(&mut self.finished)
        }

        fn verify_invariants(&self) -> Result<(), String> {
            Ok(())
        }

        fn kv_in_use(&self) -> usize {
            0
        }

        fn outstanding_by_phase(&self) -> Vec<(&'static str, usize)> {
            if self.outstanding > 0 {
                vec![("Dropped", self.outstanding)]
            } else {
                Vec::new()
            }
        }
    }

    #[test]
    fn drives_a_trace_to_completion() {
        let trace: Vec<Request> = (0..5).map(|i| req(i, i as f64 * 0.25)).collect();
        let mut sys = Fifo::new();
        let rep = sys.run(&trace);
        assert_eq!(rep.records.len(), 5);
        // FIFO with 1s service: later requests queue behind earlier ones.
        for w in rep.records.windows(2) {
            assert!(w[1].finish >= w[0].finish + 1.0 - 1e-12);
        }
    }

    #[test]
    fn empty_trace_returns_empty_report() {
        let rep = Fifo::new().run(&[]);
        assert!(rep.records.is_empty());
    }

    #[test]
    fn stats_count_dispatched_events() {
        let trace: Vec<Request> = (0..4).map(|i| req(i, i as f64)).collect();
        let mut sys = Fifo::new();
        let (rep, stats) = run_trace_with_stats(&mut sys, &trace);
        assert_eq!(rep.records.len(), 4);
        assert_eq!(stats.arrivals, 4);
        assert_eq!(stats.sys_events, 4);
        assert_eq!(stats.events, stats.arrivals + stats.sys_events + stats.ticks);
    }

    #[test]
    fn unsorted_trace_arrivals_inject_in_time_order() {
        // Lazy injection must sort by arrival, not trace position.
        let trace = vec![req(0, 2.0), req(1, 0.5), req(2, 1.0)];
        let rep = Fifo::new().run(&trace);
        let mut by_id = rep.records.clone();
        by_id.sort_by_key(|r| r.id);
        assert!(by_id[1].first_token < by_id[2].first_token);
        assert!(by_id[2].first_token < by_id[0].first_token);
    }

    #[test]
    fn tick_fires_periodically_and_stops_at_completion() {
        let trace: Vec<Request> = (0..3).map(|i| req(i, 0.0)).collect();
        let mut sys = Fifo::new();
        sys.tick_every = Some(0.5);
        sys.run(&trace);
        // 3 sequential 1s services finish at t=3; ticks at 0.5, 1.0, ...
        assert!(sys.ticks >= 4, "ticks = {}", sys.ticks);
        assert!(sys.ticks <= 7, "tick must not outlive the run: {}", sys.ticks);
    }

    #[test]
    #[should_panic(expected = "simulation stalled")]
    fn stall_detection_panics_with_progress_count() {
        let mut sys = Fifo::new();
        sys.drop_all = true;
        sys.run(&[req(0, 0.0)]);
    }

    #[test]
    #[should_panic(expected = "outstanding by phase: Dropped=1")]
    fn stall_panic_includes_phase_histogram() {
        let mut sys = Fifo::new();
        sys.drop_all = true;
        sys.run(&[req(0, 0.0)]);
    }

    #[test]
    #[should_panic(expected = "simulation stalled")]
    fn tick_driven_stall_panics_instead_of_spinning() {
        // A periodic tick keeps the queue nonempty forever; the idle-tick
        // counter must still detect that no progress is possible.
        let mut sys = Fifo::new();
        sys.drop_all = true;
        sys.tick_every = Some(0.5);
        sys.run(&[req(0, 0.0)]);
    }
}
