//! The shared trace driver every serving system runs on.
//!
//! Historically `EmpSystem`, `CoupledVllm`, and `DecoupledStatic` each
//! hand-rolled a near-identical discrete-event loop (arrival injection,
//! pop-dispatch, stall detection, report collection). That duplication is
//! now owned once, here: a system implements [`ServingSystem`] —
//! `route` new requests, `on_event` its own events, optionally a periodic
//! tick — and [`run_trace`] drives it to completion. Benchmarks compare
//! systems through this one driver, so the comparison is apples-to-apples
//! by construction, and a new baseline or scheduling policy is one
//! trait impl away.

use crate::metrics::{Report, RequestRecord};
use crate::sim::engine::EventQueue;
use crate::workload::Request;

/// Driver-level event wrapper. Arrival injection and periodic ticks are
/// owned by the driver; `Sys` carries a system-specific event.
enum DriverEv<E> {
    Arrive(usize),
    Tick,
    Sys(E),
}

/// The system-facing view of the event queue: systems read the clock and
/// schedule their own events, while arrival and tick bookkeeping stay
/// with the driver.
pub struct SimQueue<'a, E> {
    inner: &'a mut EventQueue<DriverEv<E>>,
}

impl<'a, E> SimQueue<'a, E> {
    /// Current simulation time.
    pub fn now(&self) -> f64 {
        self.inner.now()
    }

    /// Schedule a system event at absolute time `t`.
    pub fn push(&mut self, t: f64, ev: E) {
        self.inner.push(t, DriverEv::Sys(ev));
    }

    /// Schedule a system event after a delay.
    pub fn push_after(&mut self, delay: f64, ev: E) {
        self.inner.push_after(delay, DriverEv::Sys(ev));
    }
}

/// A serving system that can be driven over a request trace by
/// [`run_trace`]. Implementations own *policy* (what to do with a
/// request or event); the driver owns *mechanism* (the event loop).
pub trait ServingSystem {
    /// System-specific event type (iteration completions, migrations...).
    type Ev;

    /// Handle a newly arrived request (the driver injects arrivals from
    /// the trace at their `arrival` timestamps).
    fn route(&mut self, req: Request, q: &mut SimQueue<'_, Self::Ev>);

    /// Handle a system-specific event previously pushed onto `q`.
    fn on_event(&mut self, ev: Self::Ev, q: &mut SimQueue<'_, Self::Ev>);

    /// Interval of the periodic driver tick, if the system wants one
    /// (e.g. EMP's proactive rebalance, §3.1). The driver re-arms the
    /// tick until the run completes.
    fn tick_interval(&self) -> Option<f64> {
        None
    }

    /// Periodic tick handler (only called when [`Self::tick_interval`]
    /// returns `Some`).
    fn on_tick(&mut self, _q: &mut SimQueue<'_, Self::Ev>) {}

    /// Number of requests completed so far (drives [`Self::is_done`] and
    /// the stall diagnostic).
    fn completed(&self) -> usize;

    /// Whether the run is finished for a trace of `total` requests.
    fn is_done(&self, total: usize) -> bool {
        self.completed() >= total
    }

    /// Drain the completed-request records accumulated during the run.
    fn drain_records(&mut self) -> Vec<RequestRecord>;

    /// Cross-instance consistency checks (used by tests). Required so
    /// new systems cannot silently opt out of the driver contract.
    fn verify_invariants(&self) -> Result<(), String>;

    /// KV-cache tokens currently allocated across all instances. Must
    /// be zero after a completed run (`tests/driver_contract.rs`
    /// asserts this uniformly). Required — a `0` default would make
    /// the leak check vacuous for systems that forget to implement it.
    fn kv_in_use(&self) -> usize;

    /// Run a trace to completion through the shared driver.
    fn run(&mut self, trace: &[Request]) -> Report
    where
        Self: Sized,
    {
        run_trace(self, trace)
    }
}

/// The generic discrete-event loop: inject arrivals, arm the periodic
/// tick, dispatch events until every request finished, and collect the
/// [`Report`]. Panics with a stall diagnostic if the event queue drains
/// while requests are still outstanding — a scheduling-policy bug, never
/// a workload property.
pub fn run_trace<S: ServingSystem + ?Sized>(sys: &mut S, trace: &[Request]) -> Report {
    // Consecutive ticks with an otherwise-empty queue and no completion
    // progress before we declare a stall. One idle tick is legitimate
    // (e.g. a role-flip cooldown can defer work to the next tick);
    // several in a row mean no event will ever fire again.
    const MAX_IDLE_TICKS: u32 = 3;
    let total = trace.len();
    let mut q: EventQueue<DriverEv<S::Ev>> = EventQueue::new();
    for (i, r) in trace.iter().enumerate() {
        q.push(r.arrival, DriverEv::Arrive(i));
    }
    if let Some(dt) = sys.tick_interval() {
        q.push(dt, DriverEv::Tick);
    }
    let mut idle_ticks = 0u32;
    while !sys.is_done(total) {
        let Some((_, ev)) = q.pop() else {
            panic!(
                "simulation stalled: {}/{} requests finished",
                sys.completed(),
                total
            );
        };
        match ev {
            DriverEv::Arrive(i) => {
                idle_ticks = 0;
                sys.route(trace[i].clone(), &mut SimQueue { inner: &mut q });
            }
            DriverEv::Sys(e) => {
                idle_ticks = 0;
                sys.on_event(e, &mut SimQueue { inner: &mut q });
            }
            DriverEv::Tick => {
                let before = sys.completed();
                sys.on_tick(&mut SimQueue { inner: &mut q });
                if let Some(dt) = sys.tick_interval() {
                    if !sys.is_done(total) {
                        // A tick-driven system keeps the queue nonempty
                        // forever via re-arming, so the empty-queue stall
                        // check above never fires for it: detect
                        // no-progress idle ticks instead.
                        if q.is_empty() && sys.completed() == before {
                            idle_ticks += 1;
                            if idle_ticks >= MAX_IDLE_TICKS {
                                panic!(
                                    "simulation stalled: {}/{} requests finished \
                                     ({idle_ticks} consecutive idle ticks)",
                                    sys.completed(),
                                    total
                                );
                            }
                        } else {
                            idle_ticks = 0;
                        }
                        q.push_after(dt, DriverEv::Tick);
                    }
                }
            }
        }
    }
    Report::new(sys.drain_records())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Request;

    fn req(id: u64, arrival: f64) -> Request {
        Request {
            id,
            arrival,
            prompt_tokens: 10,
            output_tokens: 2,
            images: Vec::new(),
            prefix_id: 0,
            prefix_tokens: 0,
        }
    }

    /// A single-server FIFO toy system: each request takes 1s of service.
    struct Fifo {
        busy_until: f64,
        finished: Vec<RequestRecord>,
        ticks: usize,
        drop_all: bool,
        tick_every: Option<f64>,
    }

    impl Fifo {
        fn new() -> Fifo {
            Fifo {
                busy_until: 0.0,
                finished: Vec::new(),
                ticks: 0,
                drop_all: false,
                tick_every: None,
            }
        }
    }

    enum FifoEv {
        Done(RequestRecord),
    }

    impl ServingSystem for Fifo {
        type Ev = FifoEv;

        fn route(&mut self, req: Request, q: &mut SimQueue<'_, FifoEv>) {
            if self.drop_all {
                return; // simulate a lost request → stall
            }
            let start = self.busy_until.max(q.now());
            let finish = start + 1.0;
            self.busy_until = finish;
            let rec = RequestRecord {
                id: req.id,
                multimodal: false,
                input_len: req.prompt_tokens,
                output_len: req.output_tokens,
                arrival: req.arrival,
                first_token: start,
                finish,
            };
            q.push(finish, FifoEv::Done(rec));
        }

        fn on_event(&mut self, ev: FifoEv, _q: &mut SimQueue<'_, FifoEv>) {
            let FifoEv::Done(rec) = ev;
            self.finished.push(rec);
        }

        fn tick_interval(&self) -> Option<f64> {
            self.tick_every
        }

        fn on_tick(&mut self, _q: &mut SimQueue<'_, FifoEv>) {
            self.ticks += 1;
        }

        fn completed(&self) -> usize {
            self.finished.len()
        }

        fn drain_records(&mut self) -> Vec<RequestRecord> {
            std::mem::take(&mut self.finished)
        }

        fn verify_invariants(&self) -> Result<(), String> {
            Ok(())
        }

        fn kv_in_use(&self) -> usize {
            0
        }
    }

    #[test]
    fn drives_a_trace_to_completion() {
        let trace: Vec<Request> = (0..5).map(|i| req(i, i as f64 * 0.25)).collect();
        let mut sys = Fifo::new();
        let rep = sys.run(&trace);
        assert_eq!(rep.records.len(), 5);
        // FIFO with 1s service: later requests queue behind earlier ones.
        for w in rep.records.windows(2) {
            assert!(w[1].finish >= w[0].finish + 1.0 - 1e-12);
        }
    }

    #[test]
    fn empty_trace_returns_empty_report() {
        let rep = Fifo::new().run(&[]);
        assert!(rep.records.is_empty());
    }

    #[test]
    fn tick_fires_periodically_and_stops_at_completion() {
        let trace: Vec<Request> = (0..3).map(|i| req(i, 0.0)).collect();
        let mut sys = Fifo::new();
        sys.tick_every = Some(0.5);
        sys.run(&trace);
        // 3 sequential 1s services finish at t=3; ticks at 0.5, 1.0, ...
        assert!(sys.ticks >= 4, "ticks = {}", sys.ticks);
        assert!(sys.ticks <= 7, "tick must not outlive the run: {}", sys.ticks);
    }

    #[test]
    #[should_panic(expected = "simulation stalled")]
    fn stall_detection_panics_with_progress_count() {
        let mut sys = Fifo::new();
        sys.drop_all = true;
        sys.run(&[req(0, 0.0)]);
    }

    #[test]
    #[should_panic(expected = "simulation stalled")]
    fn tick_driven_stall_panics_instead_of_spinning() {
        // A periodic tick keeps the queue nonempty forever; the idle-tick
        // counter must still detect that no progress is possible.
        let mut sys = Fifo::new();
        sys.drop_all = true;
        sys.tick_every = Some(0.5);
        sys.run(&[req(0, 0.0)]);
    }
}
