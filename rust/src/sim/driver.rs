//! The shared trace driver every serving system runs on.
//!
//! Historically `EmpSystem`, `CoupledVllm`, and `DecoupledStatic` each
//! hand-rolled a near-identical discrete-event loop (arrival injection,
//! pop-dispatch, stall detection, report collection). That duplication is
//! now owned once, here: a system implements [`ServingSystem`] —
//! `route` new requests, `on_event` its own events, optionally a periodic
//! tick — and [`run_trace`] drives it to completion. Benchmarks compare
//! systems through this one driver, so the comparison is apples-to-apples
//! by construction, and a new baseline or scheduling policy is one
//! trait impl away.
//!
//! Arrivals are injected *lazily*: only the next pending arrival sits in
//! the queue at any time. Besides keeping the heap small, this lets the
//! driver tell systems the time of the next **external** event (next
//! arrival or periodic tick) via [`SimQueue::next_external_time`] — the
//! coalescing horizon used by decode fast-forwarding in systems whose
//! instances are independent between arrivals (the coupled baselines).
//! [`SimQueue::peek_next_time`] exposes the global horizon (earliest
//! event of any kind) for systems with cross-instance coupling.
//!
//! Requests come from a [`TraceSource`] — a materialized slice
//! ([`SliceSource`]), any iterator ([`IterSource`]), or a streaming
//! [`TraceReader`](crate::workload::trace::TraceReader) over a file that
//! never fits in memory. A bounded look-ahead heap of `lookahead`
//! pending requests re-sorts arrivals locally, so the next-arrival
//! horizon the fast-forward paths rely on stays *exact* for any source
//! whose disorder fits inside the window: the true next arrival is
//! always in the heap, hence `next_external_time` never under-reports.
//! A request surfacing *behind* an already-injected arrival means the
//! source was more disordered than the window — the driver returns an
//! error instead of silently perturbing horizons.

use crate::metrics::{Report, RequestRecord};
use crate::sim::engine::{EventQueue, QueueTelemetry};
use crate::sim::tracelog::{self, TraceLog};
use crate::util::error::Result;
use crate::workload::Request;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Driver-level event wrapper. Arrival injection and periodic ticks are
/// owned by the driver; `Sys` carries a system-specific event.
enum DriverEv<E> {
    Arrive(Request),
    Tick,
    Sys(E),
}

/// Times of the next driver-owned (external) events, snapshotted for the
/// duration of one event dispatch. `None` = no such event pending.
#[derive(Debug, Clone, Copy, Default)]
struct ExternalTimes {
    arrival: Option<f64>,
    tick: Option<f64>,
}

impl ExternalTimes {
    fn min(&self) -> Option<f64> {
        match (self.arrival, self.tick) {
            (Some(a), Some(t)) => Some(a.min(t)),
            (a, t) => a.or(t),
        }
    }
}

/// The system-facing view of the event queue: systems read the clock and
/// schedule their own events, while arrival and tick bookkeeping stay
/// with the driver.
pub struct SimQueue<'a, E> {
    inner: &'a mut EventQueue<DriverEv<E>>,
    ext: ExternalTimes,
}

impl<'a, E> SimQueue<'a, E> {
    /// Current simulation time.
    pub fn now(&self) -> f64 {
        self.inner.now()
    }

    /// Schedule a system event at absolute time `t`.
    pub fn push(&mut self, t: f64, ev: E) {
        self.inner.push(t, DriverEv::Sys(ev));
    }

    /// Schedule a system event after a delay.
    pub fn push_after(&mut self, delay: f64, ev: E) {
        self.inner.push_after(delay, DriverEv::Sys(ev));
    }

    /// Global coalescing horizon: the time of the earliest queued event
    /// of *any* kind. Nothing in the simulation can change strictly
    /// before this time, so a decode batch whose every step completes
    /// strictly earlier can be fast-forwarded without observing or
    /// perturbing anything. `None` = the queue is empty.
    pub fn peek_next_time(&self) -> Option<f64> {
        self.inner.peek_next_time()
    }

    /// External coalescing horizon: the earliest *driver-owned* event
    /// (next trace arrival or periodic tick). Valid as a fast-forward
    /// horizon only for systems whose event handlers never read or
    /// mutate another instance's decode state — then instance-local
    /// decode runs may safely overlap other instances' iteration
    /// boundaries, and only arrivals/ticks can perturb them. `None` =
    /// no arrivals left and no tick armed.
    pub fn next_external_time(&self) -> Option<f64> {
        self.ext.min()
    }
}

/// Counters from one [`run_trace_with_stats`] run — the denominator for
/// the `sim-events/sec` metric in `benches/sim_throughput.rs`.
#[derive(Debug, Clone, Copy, Default)]
pub struct DriverStats {
    /// Total events dispatched (arrivals + ticks + system events).
    pub events: u64,
    pub arrivals: u64,
    pub ticks: u64,
    pub sys_events: u64,
    /// Total pushes onto the event queue (dispatched events plus any
    /// left pending, e.g. a stale re-armed tick).
    pub queue_pushes: u64,
    /// Total pops off the event queue (equals `events` by construction
    /// — the loop dispatches exactly one event per pop).
    pub queue_pops: u64,
    /// High-water mark of events pending in the queue at once — the
    /// queue-pressure number the timing wheel's bucket adaptation (and
    /// `benches/event_queue.rs`'s scale axis) is about.
    pub peak_pending_events: usize,
    /// Timing-wheel overflow cascades (wheel re-anchors) during the run.
    pub overflow_cascades: u64,
}

impl DriverStats {
    fn absorb_queue(&mut self, qt: QueueTelemetry) {
        self.queue_pushes = qt.pushes;
        self.queue_pops = qt.pops;
        self.peak_pending_events = qt.peak_pending;
        self.overflow_cascades = qt.overflow_cascades;
    }
}

/// A pull-based supplier of trace requests, in (approximately) arrival
/// order. The driver tolerates disorder up to its look-ahead window;
/// see [`run_trace_source_with_stats`].
pub trait TraceSource {
    /// Pull the next request; `Ok(None)` = source exhausted.
    fn next_request(&mut self) -> Result<Option<Request>>;

    /// Total number of requests, when cheaply known upfront.
    fn size_hint(&self) -> Option<usize> {
        None
    }
}

/// [`TraceSource`] over a materialized slice. Pre-sorts by arrival
/// (stable by trace index for identical timestamps), so it replays in
/// exactly the order the eager driver historically used.
pub struct SliceSource<'a> {
    trace: &'a [Request],
    order: Vec<usize>,
    next: usize,
}

impl<'a> SliceSource<'a> {
    pub fn new(trace: &'a [Request]) -> SliceSource<'a> {
        let mut order: Vec<usize> = (0..trace.len()).collect();
        order.sort_by(|&a, &b| trace[a].arrival.total_cmp(&trace[b].arrival));
        SliceSource { trace, order, next: 0 }
    }
}

impl TraceSource for SliceSource<'_> {
    fn next_request(&mut self) -> Result<Option<Request>> {
        let Some(&i) = self.order.get(self.next) else {
            return Ok(None);
        };
        self.next += 1;
        Ok(Some(self.trace[i].clone()))
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.trace.len())
    }
}

/// [`TraceSource`] over any request iterator, yielded in iterator order
/// (no pre-sorting — the driver's look-ahead window does the local
/// reordering, and genuine disorder beyond it is reported as an error).
pub struct IterSource<I>(pub I);

impl<I: Iterator<Item = Request>> TraceSource for IterSource<I> {
    fn next_request(&mut self) -> Result<Option<Request>> {
        Ok(self.0.next())
    }
}

/// Cap any [`TraceSource`] at `limit` requests (the `--trace-limit`
/// CLI flag: smoke-test a prefix of a 100MB trace without reading it).
pub struct Limited<S> {
    inner: S,
    remaining: usize,
}

impl<S> Limited<S> {
    pub fn new(inner: S, limit: usize) -> Limited<S> {
        Limited { inner, remaining: limit }
    }
}

impl<S: TraceSource> TraceSource for Limited<S> {
    fn next_request(&mut self) -> Result<Option<Request>> {
        if self.remaining == 0 {
            return Ok(None);
        }
        let r = self.inner.next_request()?;
        if r.is_some() {
            self.remaining -= 1;
        }
        Ok(r)
    }

    fn size_hint(&self) -> Option<usize> {
        self.inner.size_hint().map(|n| n.min(self.remaining))
    }
}

/// Streamed trace files plug straight into the driver: one request is
/// decoded per pull, so a simulation over a 100MB trace holds only the
/// look-ahead window plus in-flight requests.
impl<R: std::io::Read> TraceSource for crate::workload::trace::TraceReader<R> {
    fn next_request(&mut self) -> Result<Option<Request>> {
        Ok(crate::workload::trace::TraceReader::next_request(self)?)
    }
}

/// Default look-ahead window for streamed sources: big enough to absorb
/// incidental local disorder, small enough to be memory-irrelevant.
pub const DEFAULT_TRACE_LOOKAHEAD: usize = 64;

/// One pending pulled-but-not-injected request in the look-ahead heap,
/// min-ordered by (arrival, pull sequence) so ties replay in source
/// order — exactly the stable sort the slice path uses.
struct Pending {
    arrival: f64,
    seq: u64,
    req: Request,
}

impl PartialEq for Pending {
    fn eq(&self, other: &Pending) -> bool {
        self.arrival.total_cmp(&other.arrival).is_eq() && self.seq == other.seq
    }
}

impl Eq for Pending {}

impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Pending) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Pending {
    fn cmp(&self, other: &Pending) -> std::cmp::Ordering {
        self.arrival.total_cmp(&other.arrival).then(self.seq.cmp(&other.seq))
    }
}

/// Top up the look-ahead heap to `lookahead` pending requests.
fn fill_lookahead<T: TraceSource + ?Sized>(
    heap: &mut BinaryHeap<Reverse<Pending>>,
    src: &mut T,
    seq: &mut u64,
    exhausted: &mut bool,
    lookahead: usize,
) -> Result<()> {
    while !*exhausted && heap.len() < lookahead {
        match src.next_request()? {
            Some(req) => {
                heap.push(Reverse(Pending { arrival: req.arrival, seq: *seq, req }));
                *seq += 1;
            }
            None => *exhausted = true,
        }
    }
    Ok(())
}

/// A serving system that can be driven over a request trace by
/// [`run_trace`]. Implementations own *policy* (what to do with a
/// request or event); the driver owns *mechanism* (the event loop).
pub trait ServingSystem {
    /// System-specific event type (iteration completions, migrations...).
    type Ev;

    /// Handle a newly arrived request (the driver injects arrivals from
    /// the trace at their `arrival` timestamps).
    fn route(&mut self, req: Request, q: &mut SimQueue<'_, Self::Ev>);

    /// Handle a system-specific event previously pushed onto `q`.
    fn on_event(&mut self, ev: Self::Ev, q: &mut SimQueue<'_, Self::Ev>);

    /// Interval of the periodic driver tick, if the system wants one
    /// (e.g. EMP's proactive rebalance, §3.1). The driver re-arms the
    /// tick until the run completes.
    fn tick_interval(&self) -> Option<f64> {
        None
    }

    /// Periodic tick handler (only called when [`Self::tick_interval`]
    /// returns `Some`).
    fn on_tick(&mut self, _q: &mut SimQueue<'_, Self::Ev>) {}

    /// Number of requests completed so far (drives [`Self::is_done`] and
    /// the stall diagnostic).
    fn completed(&self) -> usize;

    /// Whether the run is finished for a trace of `total` requests.
    fn is_done(&self, total: usize) -> bool {
        self.completed() >= total
    }

    /// Drain the completed-request records accumulated during the run.
    fn drain_records(&mut self) -> Vec<RequestRecord>;

    /// Cross-instance consistency checks (used by tests). Required so
    /// new systems cannot silently opt out of the driver contract.
    fn verify_invariants(&self) -> Result<(), String>;

    /// KV-cache tokens currently allocated across all instances. Must
    /// be zero after a completed run (`tests/driver_contract.rs`
    /// asserts this uniformly). Required — a `0` default would make
    /// the leak check vacuous for systems that forget to implement it.
    fn kv_in_use(&self) -> usize;

    /// Outstanding (not yet finished) requests bucketed by lifecycle
    /// phase, included in the driver's stall diagnostic so a policy bug
    /// is localizable from the panic message alone. Systems backed by a
    /// `RequestSlab` implement this via `RequestSlab::phase_histogram`;
    /// the default reports nothing.
    fn outstanding_by_phase(&self) -> Vec<(&'static str, usize)> {
        Vec::new()
    }

    /// Attach system-specific summary stats to the finished report
    /// (e.g. `EmpSystem` copies its elastic-TP reconfiguration counters
    /// into `Report::tp_reconfigs` / `tp_busy_gpu_seconds` /
    /// `tp_timeline`). Called once by the driver after the run
    /// completes; the default attaches nothing.
    fn annotate_report(&self, _rep: &mut Report) {}

    /// Install a [`TraceLog`] sink for the run. Systems that emit
    /// lifecycle events store the (cheaply cloned) handle; the default
    /// drops it, which leaves the system untraced.
    fn set_tracelog(&mut self, _tl: TraceLog) {}

    /// The system's current [`TraceLog`] handle. The driver snapshots
    /// this once per run to emit driver-owned events (arrivals) and the
    /// stall-panic flight-recorder tail through the same sink. The
    /// default is the no-op `Off` arm.
    fn tracelog(&self) -> TraceLog {
        TraceLog::Off
    }

    /// Run a trace to completion through the shared driver.
    fn run(&mut self, trace: &[Request]) -> Report
    where
        Self: Sized,
    {
        run_trace(self, trace)
    }
}

fn stall_message<S: ServingSystem + ?Sized>(
    sys: &S,
    total: usize,
    detail: &str,
    qt: QueueTelemetry,
    tl: &TraceLog,
) -> String {
    tracelog::format_stall(
        sys.completed(),
        total,
        detail,
        &sys.outstanding_by_phase(),
        &qt,
        &tl.tail_lines(tracelog::STALL_TAIL),
    )
}

/// The generic discrete-event loop over a pull-based [`TraceSource`]:
/// keep a look-ahead heap of up to `lookahead` pending requests, inject
/// the earliest lazily (next arrival queued *before* routing the current
/// one, so every handler sees a complete horizon), arm the periodic
/// tick, dispatch until every injected request finished.
///
/// Errors if the source fails mid-stream or surfaces a request earlier
/// than one already injected (disorder beyond the look-ahead window —
/// the horizon guarantee would silently break otherwise). Panics with a
/// stall diagnostic if the event queue drains while requests are still
/// outstanding — a scheduling-policy bug, never a workload property.
pub fn run_trace_source_with_stats<S: ServingSystem + ?Sized, T: TraceSource + ?Sized>(
    sys: &mut S,
    src: &mut T,
    lookahead: usize,
) -> Result<(Report, DriverStats)> {
    // Consecutive ticks with an otherwise-empty queue and no completion
    // progress before we declare a stall. One idle tick is legitimate
    // (e.g. a role-flip cooldown can defer work to the next tick);
    // several in a row mean no event will ever fire again.
    const MAX_IDLE_TICKS: u32 = 3;
    let lookahead = lookahead.max(1);
    let mut q: EventQueue<DriverEv<S::Ev>> = EventQueue::new();
    let mut heap: BinaryHeap<Reverse<Pending>> = BinaryHeap::new();
    let mut seq = 0u64;
    let mut exhausted = false;
    // Requests pushed into the event queue so far; the driver's running
    // notion of "total". An injected-but-unrouted request cannot be
    // completed, so `exhausted && heap empty && is_done(injected)`
    // implies no Arrive event is still pending.
    let mut injected = 0usize;
    let mut last_injected = f64::NEG_INFINITY;
    let mut ext = ExternalTimes::default();
    fill_lookahead(&mut heap, src, &mut seq, &mut exhausted, lookahead)?;
    if let Some(Reverse(p)) = heap.pop() {
        q.push(p.arrival, DriverEv::Arrive(p.req));
        ext.arrival = Some(p.arrival);
        last_injected = p.arrival;
        injected += 1;
        fill_lookahead(&mut heap, src, &mut seq, &mut exhausted, lookahead)?;
    }
    if let Some(dt) = sys.tick_interval() {
        q.push(dt, DriverEv::Tick);
        ext.tick = Some(dt);
    }
    // One snapshot per run: the handle is a cheap clone sharing the
    // system's recorder, and `Off` keeps every emission below a no-op.
    let tl = sys.tracelog();
    let mut stats = DriverStats::default();
    let mut idle_ticks = 0u32;
    while !(exhausted && heap.is_empty() && sys.is_done(injected)) {
        let Some((_, ev)) = q.pop() else {
            panic!("{}", stall_message(sys, injected, "", q.telemetry(), &tl));
        };
        stats.events += 1;
        match ev {
            DriverEv::Arrive(req) => {
                stats.arrivals += 1;
                idle_ticks = 0;
                // Driver-owned lifecycle point: the request enters the
                // simulation (opens its TTFT decomposition checkpoint).
                tl.arrival(q.now(), req.id);
                // Queue the next arrival *before* routing so every
                // handler sees a complete horizon.
                if let Some(Reverse(p)) = heap.pop() {
                    if p.arrival < last_injected {
                        crate::bail!(
                            "trace not sorted within look-ahead horizon: arrival {} \
                             surfaced after {} was already injected (window {}); sort \
                             the trace or raise the look-ahead",
                            p.arrival,
                            last_injected,
                            lookahead
                        );
                    }
                    q.push(p.arrival, DriverEv::Arrive(p.req));
                    ext.arrival = Some(p.arrival.max(q.now()));
                    last_injected = p.arrival;
                    injected += 1;
                    fill_lookahead(&mut heap, src, &mut seq, &mut exhausted, lookahead)?;
                } else {
                    ext.arrival = None;
                }
                sys.route(req, &mut SimQueue { inner: &mut q, ext });
            }
            DriverEv::Sys(e) => {
                stats.sys_events += 1;
                idle_ticks = 0;
                sys.on_event(e, &mut SimQueue { inner: &mut q, ext });
            }
            DriverEv::Tick => {
                stats.ticks += 1;
                let before = sys.completed();
                // Re-arm *before* the handler so the next tick is in the
                // queue (and in `ext`) while `on_tick` runs — both
                // coalescing horizons must stay truthful for any system
                // that reads them from a tick path. A stale tick left
                // behind by a run that completes inside `on_tick` is
                // harmless: the loop exits on the done condition.
                let done = exhausted && heap.is_empty() && sys.is_done(injected);
                let rearmed = match sys.tick_interval() {
                    Some(dt) if !done => {
                        let t = q.now() + dt.max(0.0);
                        q.push(t, DriverEv::Tick);
                        ext.tick = Some(t);
                        true
                    }
                    _ => {
                        ext.tick = None;
                        false
                    }
                };
                sys.on_tick(&mut SimQueue { inner: &mut q, ext });
                if rearmed {
                    // A tick-driven system keeps the queue nonempty
                    // forever via re-arming, so the empty-queue stall
                    // check above never fires for it: detect no-progress
                    // idle ticks instead (only the re-armed tick queued,
                    // no pending arrival, no completions).
                    if q.len() == 1 && ext.arrival.is_none() && sys.completed() == before {
                        idle_ticks += 1;
                        if idle_ticks >= MAX_IDLE_TICKS {
                            panic!(
                                "{}",
                                stall_message(
                                    sys,
                                    injected,
                                    &format!(" ({idle_ticks} consecutive idle ticks)"),
                                    q.telemetry(),
                                    &tl
                                )
                            );
                        }
                    } else {
                        idle_ticks = 0;
                    }
                }
            }
        }
    }
    stats.absorb_queue(q.telemetry());
    let mut report = Report::new(sys.drain_records());
    sys.annotate_report(&mut report);
    // Aggregated flight-recorder sections (TTFT decomposition, busy /
    // queue-depth series, reshard attribution). No-op when untraced.
    tl.fold_into_report(&mut report);
    Ok((report, stats))
}

/// [`run_trace_source_with_stats`] without the counters.
pub fn run_trace_source<S: ServingSystem + ?Sized, T: TraceSource + ?Sized>(
    sys: &mut S,
    src: &mut T,
    lookahead: usize,
) -> Result<Report> {
    Ok(run_trace_source_with_stats(sys, src, lookahead)?.0)
}

/// [`run_trace`] plus the dispatch counters (see [`DriverStats`]).
///
/// Slice-backed wrapper over the source-based loop: [`SliceSource`]
/// pre-sorts, so a look-ahead of 1 replays the exact historical
/// injection order and no source error is possible.
pub fn run_trace_with_stats<S: ServingSystem + ?Sized>(
    sys: &mut S,
    trace: &[Request],
) -> (Report, DriverStats) {
    let mut src = SliceSource::new(trace);
    run_trace_source_with_stats(sys, &mut src, 1)
        .expect("slice sources are pre-sorted and infallible")
}

/// The generic discrete-event loop: inject arrivals, arm the periodic
/// tick, dispatch events until every request finished, and collect the
/// [`Report`]. Panics with a stall diagnostic (including a per-phase
/// histogram of outstanding requests) if the event queue drains while
/// requests are still outstanding — a scheduling-policy bug, never a
/// workload property.
pub fn run_trace<S: ServingSystem + ?Sized>(sys: &mut S, trace: &[Request]) -> Report {
    run_trace_with_stats(sys, trace).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Request;

    fn req(id: u64, arrival: f64) -> Request {
        Request {
            id,
            arrival,
            prompt_tokens: 10,
            output_tokens: 2,
            media: Vec::new().into(),
            prefix_id: 0,
            prefix_tokens: 0,
        }
    }

    /// A single-server FIFO toy system: each request takes 1s of service.
    struct Fifo {
        busy_until: f64,
        finished: Vec<RequestRecord>,
        ticks: usize,
        drop_all: bool,
        tick_every: Option<f64>,
        outstanding: usize,
    }

    impl Fifo {
        fn new() -> Fifo {
            Fifo {
                busy_until: 0.0,
                finished: Vec::new(),
                ticks: 0,
                drop_all: false,
                tick_every: None,
                outstanding: 0,
            }
        }
    }

    enum FifoEv {
        Done(RequestRecord),
    }

    impl ServingSystem for Fifo {
        type Ev = FifoEv;

        fn route(&mut self, req: Request, q: &mut SimQueue<'_, FifoEv>) {
            if self.drop_all {
                self.outstanding += 1;
                return; // simulate a lost request → stall
            }
            let start = self.busy_until.max(q.now());
            let finish = start + 1.0;
            self.busy_until = finish;
            let rec = RequestRecord {
                id: req.id,
                modality: crate::workload::Modality::Text,
                input_len: req.prompt_tokens,
                output_len: req.output_tokens,
                arrival: req.arrival,
                first_token: start,
                finish,
            };
            q.push(finish, FifoEv::Done(rec));
        }

        fn on_event(&mut self, ev: FifoEv, _q: &mut SimQueue<'_, FifoEv>) {
            let FifoEv::Done(rec) = ev;
            self.finished.push(rec);
        }

        fn tick_interval(&self) -> Option<f64> {
            self.tick_every
        }

        fn on_tick(&mut self, _q: &mut SimQueue<'_, FifoEv>) {
            self.ticks += 1;
        }

        fn completed(&self) -> usize {
            self.finished.len()
        }

        fn drain_records(&mut self) -> Vec<RequestRecord> {
            std::mem::take(&mut self.finished)
        }

        fn verify_invariants(&self) -> Result<(), String> {
            Ok(())
        }

        fn kv_in_use(&self) -> usize {
            0
        }

        fn outstanding_by_phase(&self) -> Vec<(&'static str, usize)> {
            if self.outstanding > 0 {
                vec![("Dropped", self.outstanding)]
            } else {
                Vec::new()
            }
        }
    }

    #[test]
    fn drives_a_trace_to_completion() {
        let trace: Vec<Request> = (0..5).map(|i| req(i, i as f64 * 0.25)).collect();
        let mut sys = Fifo::new();
        let rep = sys.run(&trace);
        assert_eq!(rep.records.len(), 5);
        // FIFO with 1s service: later requests queue behind earlier ones.
        for w in rep.records.windows(2) {
            assert!(w[1].finish >= w[0].finish + 1.0 - 1e-12);
        }
    }

    #[test]
    fn empty_trace_returns_empty_report() {
        let rep = Fifo::new().run(&[]);
        assert!(rep.records.is_empty());
    }

    #[test]
    fn stats_count_dispatched_events() {
        let trace: Vec<Request> = (0..4).map(|i| req(i, i as f64)).collect();
        let mut sys = Fifo::new();
        let (rep, stats) = run_trace_with_stats(&mut sys, &trace);
        assert_eq!(rep.records.len(), 4);
        assert_eq!(stats.arrivals, 4);
        assert_eq!(stats.sys_events, 4);
        assert_eq!(stats.events, stats.arrivals + stats.sys_events + stats.ticks);
        // Queue telemetry: one pop per dispatched event, every pop was
        // pushed first, and at least one event was ever pending.
        assert_eq!(stats.queue_pops, stats.events);
        assert!(stats.queue_pushes >= stats.queue_pops);
        assert!(stats.peak_pending_events >= 1);
    }

    #[test]
    fn unsorted_trace_arrivals_inject_in_time_order() {
        // Lazy injection must sort by arrival, not trace position.
        let trace = vec![req(0, 2.0), req(1, 0.5), req(2, 1.0)];
        let rep = Fifo::new().run(&trace);
        let mut by_id = rep.records.clone();
        by_id.sort_by_key(|r| r.id);
        assert!(by_id[1].first_token < by_id[2].first_token);
        assert!(by_id[2].first_token < by_id[0].first_token);
    }

    #[test]
    fn tick_fires_periodically_and_stops_at_completion() {
        let trace: Vec<Request> = (0..3).map(|i| req(i, 0.0)).collect();
        let mut sys = Fifo::new();
        sys.tick_every = Some(0.5);
        sys.run(&trace);
        // 3 sequential 1s services finish at t=3; ticks at 0.5, 1.0, ...
        assert!(sys.ticks >= 4, "ticks = {}", sys.ticks);
        assert!(sys.ticks <= 7, "tick must not outlive the run: {}", sys.ticks);
    }

    #[test]
    #[should_panic(expected = "simulation stalled")]
    fn stall_detection_panics_with_progress_count() {
        let mut sys = Fifo::new();
        sys.drop_all = true;
        sys.run(&[req(0, 0.0)]);
    }

    #[test]
    #[should_panic(expected = "outstanding by phase: Dropped=1")]
    fn stall_panic_includes_phase_histogram() {
        let mut sys = Fifo::new();
        sys.drop_all = true;
        sys.run(&[req(0, 0.0)]);
    }

    #[test]
    #[should_panic(expected = "simulation stalled")]
    fn tick_driven_stall_panics_instead_of_spinning() {
        // A periodic tick keeps the queue nonempty forever; the idle-tick
        // counter must still detect that no progress is possible.
        let mut sys = Fifo::new();
        sys.drop_all = true;
        sys.tick_every = Some(0.5);
        sys.run(&[req(0, 0.0)]);
    }

    // -- TraceSource paths ----------------------------------------------

    #[test]
    fn iterator_source_matches_slice_run() {
        let trace: Vec<Request> = (0..20).map(|i| req(i, i as f64 * 0.3)).collect();
        let slice_rep = Fifo::new().run(&trace);
        for lookahead in [1, 4, DEFAULT_TRACE_LOOKAHEAD] {
            let mut sys = Fifo::new();
            let mut src = IterSource(trace.iter().cloned());
            let (rep, stats) =
                run_trace_source_with_stats(&mut sys, &mut src, lookahead).unwrap();
            assert_eq!(rep.records.len(), slice_rep.records.len());
            assert_eq!(stats.arrivals, 20);
            for (a, b) in slice_rep.records.iter().zip(&rep.records) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.first_token, b.first_token);
                assert_eq!(a.finish, b.finish);
            }
        }
    }

    #[test]
    fn lookahead_absorbs_local_disorder() {
        // Shuffled within a window of 3: a look-ahead of 4 must re-sort
        // it into the same schedule as the pre-sorted slice path.
        let shuffled = vec![req(1, 0.5), req(0, 0.2), req(2, 0.9), req(4, 2.0), req(3, 1.4)];
        let slice_rep = Fifo::new().run(&shuffled);
        let mut sys = Fifo::new();
        let mut src = IterSource(shuffled.iter().cloned());
        let rep = run_trace_source(&mut sys, &mut src, 4).unwrap();
        for (a, b) in slice_rep.records.iter().zip(&rep.records) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.finish, b.finish);
        }
    }

    #[test]
    fn disorder_beyond_lookahead_errors() {
        // With a window of 1 the driver injects 2.0 first, then sees 0.5
        // — an order violation it must report, not silently absorb.
        let trace = vec![req(0, 2.0), req(1, 0.5)];
        let mut sys = Fifo::new();
        let mut src = IterSource(trace.into_iter());
        let err = run_trace_source(&mut sys, &mut src, 1)
            .expect_err("disorder beyond the window must error");
        assert!(err.to_string().contains("look-ahead"), "got: {err}");
    }

    #[test]
    fn limited_source_caps_request_count() {
        let trace: Vec<Request> = (0..10).map(|i| req(i, i as f64 * 0.1)).collect();
        let mut sys = Fifo::new();
        let mut src = Limited::new(SliceSource::new(&trace), 4);
        assert_eq!(src.size_hint(), Some(4));
        let rep = run_trace_source(&mut sys, &mut src, 8).unwrap();
        assert_eq!(rep.records.len(), 4);
    }
}
