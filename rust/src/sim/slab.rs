//! Dense request storage for the simulation hot path.
//!
//! Every serving system used to keep its in-flight requests in a
//! `HashMap<u64, SimRequest>` and look them up by trace id on every
//! decode step — one hash per sequence per generated token. The slab
//! replaces that with a `Vec<SimRequest>` keyed by a small dense
//! [`ReqIx`] handed out once at routing; instances, wait queues and
//! iteration snapshots all carry `ReqIx`, so the per-token path is a
//! bounds-checked array index. Requests are never removed (a finished
//! request keeps its slot until the run ends), which keeps indices
//! stable for the whole simulation.

use crate::sim::instance::{Phase, SimRequest};

/// Dense index of a request within a [`RequestSlab`]. `u32` keeps the
/// per-instance `decoding` lists and iteration snapshots compact.
pub type ReqIx = u32;

/// Append-only arena of [`SimRequest`]s, indexed by [`ReqIx`].
#[derive(Debug, Default)]
pub struct RequestSlab {
    items: Vec<SimRequest>,
}

impl RequestSlab {
    pub fn new() -> RequestSlab {
        RequestSlab { items: Vec::new() }
    }

    /// Insert at routing time; the returned index is the request's
    /// identity for the rest of the run.
    pub fn insert(&mut self, r: SimRequest) -> ReqIx {
        let ix = self.items.len() as ReqIx;
        self.items.push(r);
        ix
    }

    pub fn get(&self, ix: ReqIx) -> &SimRequest {
        &self.items[ix as usize]
    }

    pub fn get_mut(&mut self, ix: ReqIx) -> &mut SimRequest {
        &mut self.items[ix as usize]
    }

    /// Checked access for invariant verification (an out-of-range index
    /// is a scheduler bug, reported rather than panicking mid-check).
    pub fn try_get(&self, ix: ReqIx) -> Option<&SimRequest> {
        self.items.get(ix as usize)
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &SimRequest> {
        self.items.iter()
    }

    /// Outstanding (non-finished) requests per lifecycle phase, for the
    /// driver's stall diagnostic. Order matches the [`Phase`] pipeline.
    pub fn phase_histogram(&self) -> Vec<(&'static str, usize)> {
        let mut counts = [0usize; Phase::COUNT];
        for r in &self.items {
            counts[r.phase.index()] += 1;
        }
        Phase::ALL
            .iter()
            .filter(|p| **p != Phase::Finished)
            .map(|p| (p.name(), counts[p.index()]))
            .collect()
    }
}

/// Small pool of retired `Vec<ReqIx>` decode-batch snapshots, so the
/// per-iteration `ids` buffer is reused instead of freshly allocated
/// (hot-path allocation elimination; shared by every serving system).
#[derive(Debug, Default)]
pub struct IdsPool {
    free: Vec<Vec<ReqIx>>,
}

impl IdsPool {
    /// Take an empty buffer (pooled if available).
    pub fn take(&mut self) -> Vec<ReqIx> {
        self.free.pop().unwrap_or_default()
    }

    /// Return a retired buffer to the pool (bounded so a pathological
    /// burst can't hoard memory forever).
    pub fn recycle(&mut self, mut v: Vec<ReqIx>) {
        v.clear();
        if self.free.len() < 64 {
            self.free.push(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Request;

    fn req(id: u64) -> SimRequest {
        SimRequest::new(
            Request {
                id,
                arrival: 0.0,
                prompt_tokens: 10,
                output_tokens: 4,
                media: Vec::new().into(),
                prefix_id: 0,
                prefix_tokens: 0,
            },
            0,
        )
    }

    #[test]
    fn insert_returns_dense_indices() {
        let mut s = RequestSlab::new();
        assert!(s.is_empty());
        let a = s.insert(req(10));
        let b = s.insert(req(20));
        assert_eq!((a, b), (0, 1));
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a).req.id, 10);
        assert_eq!(s.get(b).req.id, 20);
        s.get_mut(a).decoded = 3;
        assert_eq!(s.get(a).decoded, 3);
        assert!(s.try_get(2).is_none());
    }

    #[test]
    fn phase_histogram_counts_outstanding() {
        let mut s = RequestSlab::new();
        let a = s.insert(req(1));
        let b = s.insert(req(2));
        let c = s.insert(req(3));
        s.get_mut(a).phase = Phase::Decoding;
        s.get_mut(b).phase = Phase::Decoding;
        s.get_mut(c).phase = Phase::Finished;
        let h = s.phase_histogram();
        assert!(h.iter().all(|(name, _)| *name != "Finished"));
        let decoding = h.iter().find(|(n, _)| *n == "Decoding").unwrap().1;
        assert_eq!(decoding, 2);
        let total: usize = h.iter().map(|(_, c)| c).sum();
        assert_eq!(total, 2, "finished requests are not outstanding");
    }
}
