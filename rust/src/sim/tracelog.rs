//! Simulator flight recorder: structured lifecycle tracing, Perfetto
//! export, and utilization time-series (DESIGN.md §13).
//!
//! One [`TraceLog`] handle is threaded through the shared driver and
//! every [`ServingSystem`](crate::sim::driver::ServingSystem) so all
//! five variants emit the same typed lifecycle events: arrival,
//! queue-enter/exit, encode/prefill/decode iteration spans, first
//! token, decode fast-forward windows, KV migration, TP reshard busy
//! windows, cache hits, role flips, and completion. The sink fans each
//! event three ways:
//!
//! * a bounded **ring buffer** (last [`RING_CAP`] events) whose tail is
//!   dumped into stall panics and readable on demand;
//! * an optional **Chrome trace-event / Perfetto stream** through the
//!   existing [`JsonWriter`] (`simulate --trace-out run.json`) —
//!   constant memory, pid = modality group, tid = instance, `B`/`E`
//!   spans, `X` complete events for fast-forward and migration windows,
//!   `i` instants, `C` counter tracks for per-group queue depth;
//! * bounded **aggregation state**: per-request TTFT checkpoints (a
//!   `BTreeMap` pruned at first token — never the full request set at
//!   once), per-group GPU-busy and queue-depth [`TimeSeries`] (≤
//!   [`MAX_BUCKETS`] buckets, adaptively coarsened), and reshard-shadow
//!   attribution, folded into `Report::observability` deterministically.
//!
//! **Zero-cost when off:** the disabled sink is a unit enum arm
//! ([`TraceLog::Off`], the `Default`); every emission method matches on
//! it and returns immediately, no state exists, and Reports are
//! byte-identical to an untraced build
//! (`tests/tracelog_equivalence.rs` asserts this across all variants ×
//! fast-forward on/off; `benches/trace_overhead.rs` gates the
//! wall-clock overhead).
//!
//! The module is also the home of the unified timeline model: the
//! [`TpReconfig`] record (re-exported from `metrics` for
//! compatibility) and the stall-panic formatting helper
//! [`format_stall`] that merges the phase histogram, the
//! [`QueueTelemetry`] pressure line, and the flight-recorder tail into
//! one message.

use crate::metrics::Report;
use crate::sim::engine::QueueTelemetry;
use crate::util::json::{Json, JsonEvent, JsonReader, JsonWriter};

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::io;
use std::rc::Rc;

/// One TP-reconfiguration event for the report's `tp_timeline`
/// (merge/split audit trail, DESIGN.md §7). Lives here so the elastic-TP
/// timeline, the flight recorder, and the Perfetto stream share one
/// timeline model; `crate::metrics` re-exports it unchanged.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TpReconfig {
    /// Sim time the reconfiguration began.
    pub t: f64,
    /// Modality-group index it happened in.
    pub group: usize,
    /// Leader instance id (the slot that stays live).
    pub instance: usize,
    /// TP degree after the reconfiguration.
    pub tp_after: usize,
    /// true = merge (widen), false = split (narrow).
    pub merge: bool,
}

impl TpReconfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("t", Json::num(self.t)),
            ("group", Json::u64(self.group as u64)),
            ("instance", Json::u64(self.instance as u64)),
            ("tp_after", Json::u64(self.tp_after as u64)),
            ("merge", Json::Bool(self.merge)),
        ])
    }
}

/// Ring-buffer capacity: enough context to reconstruct the last few
/// scheduling rounds at every fleet size the simulator models, small
/// enough that the recorder's memory is trivially bounded.
pub const RING_CAP: usize = 256;
/// How much of the ring a stall panic dumps.
pub const STALL_TAIL: usize = 64;
/// Time-series resolution bound: buckets double in width whenever a run
/// outgrows this count, so memory stays O(64) per track at any horizon.
pub const MAX_BUCKETS: usize = 64;

/// Iteration span categories (`B`/`E` pairs on an instance track).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    Encode,
    Prefill,
    Decode,
    Reshard,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Encode => "encode",
            SpanKind::Prefill => "prefill",
            SpanKind::Decode => "decode",
            SpanKind::Reshard => "reshard",
        }
    }
}

/// Complete-window categories (`X` events: duration known at emission,
/// no begin/end pairing — fast-forward coalesces many steps into one
/// window, migration starts and lands on different tracks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WindowKind {
    DecodeFastForward,
    Migration,
}

impl WindowKind {
    pub fn name(self) -> &'static str {
        match self {
            WindowKind::DecodeFastForward => "decode-ff",
            WindowKind::Migration => "migration",
        }
    }
}

/// Instantaneous lifecycle marks (`i` events).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mark {
    Arrival,
    QueueEnter,
    QueueExit,
    FirstToken,
    CacheHit,
    Completion,
    RoleFlip,
    TpMerge,
    TpSplit,
}

impl Mark {
    pub fn name(self) -> &'static str {
        match self {
            Mark::Arrival => "arrival",
            Mark::QueueEnter => "queue-enter",
            Mark::QueueExit => "queue-exit",
            Mark::FirstToken => "first-token",
            Mark::CacheHit => "cache-hit",
            Mark::Completion => "completion",
            Mark::RoleFlip => "role-flip",
            Mark::TpMerge => "tp-merge",
            Mark::TpSplit => "tp-split",
        }
    }
}

/// One recorded event (ring-buffer entry).
#[derive(Debug, Clone, Copy)]
pub struct Ev {
    pub t: f64,
    /// Perfetto pid: modality-group index (or fleet index for the
    /// decoupled baseline).
    pub pid: u32,
    /// Perfetto tid: instance id within the cluster.
    pub tid: u32,
    pub kind: EvKind,
}

#[derive(Debug, Clone, Copy)]
pub enum EvKind {
    Begin(SpanKind),
    End(SpanKind),
    /// Complete window with its duration in seconds.
    Window(WindowKind, f64),
    /// Mark with its payload (request id; role index for `RoleFlip`).
    Mark(Mark, u64),
    /// Queue-depth counter sample for the pid's group.
    Counter(f64),
}

impl Ev {
    /// Human-readable one-liner for stall panics and `tail_lines`.
    pub fn line(&self) -> String {
        let head = format!("t={:>10.4} g{}/i{} ", self.t, self.pid, self.tid);
        match self.kind {
            EvKind::Begin(k) => format!("{head}B {}", k.name()),
            EvKind::End(k) => format!("{head}E {}", k.name()),
            EvKind::Window(k, d) => format!("{head}X {} {:.4}s", k.name(), d),
            EvKind::Mark(m, id) => format!("{head}{} id={id}", m.name()),
            EvKind::Counter(v) => format!("{head}queue-depth={v}"),
        }
    }
}

/// Fixed-capacity ring of the most recent [`RING_CAP`] events.
#[derive(Debug, Default)]
struct Ring {
    buf: Vec<Ev>,
    /// Next write slot (== oldest entry once the ring is full).
    next: usize,
    total: u64,
}

impl Ring {
    fn push(&mut self, ev: Ev) {
        if self.buf.len() < RING_CAP {
            self.buf.push(ev);
        } else {
            self.buf[self.next] = ev;
        }
        self.next = (self.next + 1) % RING_CAP;
        self.total += 1;
    }

    /// Last `n` events, oldest first.
    fn tail(&self, n: usize) -> Vec<Ev> {
        let take = n.min(self.buf.len());
        (0..take)
            .map(|k| self.buf[(self.next + RING_CAP - take + k) % RING_CAP])
            .collect()
    }
}

/// Bounded utilization time-series: the integral of a rate over time,
/// bucketed; buckets double in width (adjacent pairs merge, preserving
/// the integral) whenever the run outgrows [`MAX_BUCKETS`].
#[derive(Debug, Clone)]
pub struct TimeSeries {
    width: f64,
    vals: Vec<f64>,
}

impl Default for TimeSeries {
    fn default() -> Self {
        TimeSeries { width: 0.5, vals: Vec::new() }
    }
}

impl TimeSeries {
    pub fn bucket_width(&self) -> f64 {
        self.width
    }

    pub fn values(&self) -> &[f64] {
        &self.vals
    }

    /// Total integral across all buckets.
    pub fn total(&self) -> f64 {
        self.vals.iter().sum()
    }

    fn coarsen(&mut self) {
        let mut merged = Vec::with_capacity(self.vals.len().div_ceil(2));
        let mut i = 0;
        while i < self.vals.len() {
            let b = if i + 1 < self.vals.len() { self.vals[i + 1] } else { 0.0 };
            merged.push(self.vals[i] + b);
            i += 2;
        }
        self.vals = merged;
        self.width *= 2.0;
    }

    /// Accumulate `rate` over `[t0, t0 + dur)`, split across buckets.
    pub fn add(&mut self, t0: f64, dur: f64, rate: f64) {
        if !(t0.is_finite() && dur > 0.0) || rate == 0.0 {
            return;
        }
        let t0 = t0.max(0.0);
        let t1 = t0 + dur;
        while t1 >= self.width * MAX_BUCKETS as f64 {
            self.coarsen();
        }
        let mut a = t0;
        while a < t1 {
            let ix = ((a / self.width) as usize).min(MAX_BUCKETS - 1);
            let end = t1.min((ix as f64 + 1.0) * self.width);
            if self.vals.len() <= ix {
                self.vals.resize(ix + 1, 0.0);
            }
            self.vals[ix] += (end - a) * rate;
            if end <= a {
                break; // fp guard: a sits exactly on a degenerate boundary
            }
            a = end;
        }
    }

    fn to_json(&self, key: &str) -> Json {
        Json::obj(vec![
            ("bucket_s", Json::num(self.width)),
            (key, Json::arr_f64(&self.vals)),
        ])
    }
}

/// Step-function sampler feeding a [`TimeSeries`]: each sample closes
/// the segment `[last_t, t)` at the previous value.
#[derive(Debug, Clone, Default)]
struct StepSampler {
    last_t: f64,
    last_v: f64,
    series: TimeSeries,
}

impl StepSampler {
    fn sample(&mut self, t: f64, v: f64) {
        if t > self.last_t {
            self.series.add(self.last_t, t - self.last_t, self.last_v);
            self.last_t = t;
        }
        self.last_v = v;
    }
}

/// Per-request TTFT checkpoints (NaN = not reached). Pruned at first
/// token, so the map never holds the whole trace.
#[derive(Debug, Clone, Copy)]
struct Ckpt {
    arrival: f64,
    enc_start: f64,
    enc_done: f64,
    pref_start: f64,
}

/// Per-request TTFT decomposition: `queue + encode + prefill` telescopes
/// to `first_token - arrival` by construction (each checkpoint is
/// clamped into the windows of its successors, so out-of-order or
/// missing stamps degrade gracefully instead of going negative).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecompRec {
    pub id: u64,
    pub queue_s: f64,
    pub encode_s: f64,
    pub prefill_s: f64,
    pub ttft_s: f64,
}

fn decompose(id: u64, ck: Ckpt, first_token: f64) -> DecompRec {
    let a = ck.arrival;
    let f = first_token.max(a);
    let es = if ck.enc_start.is_nan() { a } else { ck.enc_start.clamp(a, f) };
    let ed = if ck.enc_done.is_nan() { es } else { ck.enc_done.clamp(es, f) };
    let ps = if ck.pref_start.is_nan() { f } else { ck.pref_start.clamp(ed, f) };
    DecompRec {
        id,
        queue_s: (es - a) + (ps - ed),
        encode_s: ed - es,
        prefill_s: f - ps,
        ttft_s: f - a,
    }
}

/// Streaming Perfetto sink: the first I/O error is stashed and
/// surfaced at [`TraceLog::finish_perfetto`] so emission sites stay
/// infallible.
struct Perfetto {
    w: JsonWriter<Box<dyn io::Write>>,
    err: Option<io::Error>,
}

impl Perfetto {
    fn emit(&mut self, f: impl FnOnce(&mut JsonWriter<Box<dyn io::Write>>) -> io::Result<()>) {
        if self.err.is_none() {
            if let Err(e) = f(&mut self.w) {
                self.err = Some(e);
            }
        }
    }
}

/// The recorder behind an enabled [`TraceLog`].
#[derive(Default)]
pub struct TraceState {
    ring: Ring,
    perfetto: Option<Perfetto>,
    ckpts: BTreeMap<u64, Ckpt>,
    decomp: Vec<DecompRec>,
    gpu_busy: BTreeMap<u32, TimeSeries>,
    queue_depth: BTreeMap<u32, StepSampler>,
    reshard_busy_gpu_s: f64,
    reshard_windows: u64,
    tp_events: u64,
}

impl TraceState {
    fn record(&mut self, ev: Ev) {
        if let Some(p) = self.perfetto.as_mut() {
            write_perfetto_event(p, &ev);
        }
        self.ring.push(ev);
    }
}

fn write_perfetto_event(p: &mut Perfetto, ev: &Ev) {
    let ts = ev.t * 1e6; // Chrome trace-event timestamps are microseconds
    let (pid, tid) = (ev.pid as f64, ev.tid as f64);
    match ev.kind {
        EvKind::Begin(k) | EvKind::End(k) => p.emit(|w| {
            w.begin_object()?;
            w.key("name")?;
            w.string(k.name())?;
            w.key("ph")?;
            w.string(if matches!(ev.kind, EvKind::Begin(_)) { "B" } else { "E" })?;
            w.key("pid")?;
            w.num(pid)?;
            w.key("tid")?;
            w.num(tid)?;
            w.key("ts")?;
            w.num(ts)?;
            w.end_object()
        }),
        EvKind::Window(k, dur) => p.emit(|w| {
            w.begin_object()?;
            w.key("name")?;
            w.string(k.name())?;
            w.key("ph")?;
            w.string("X")?;
            w.key("pid")?;
            w.num(pid)?;
            w.key("tid")?;
            w.num(tid)?;
            w.key("ts")?;
            w.num(ts)?;
            w.key("dur")?;
            w.num(dur * 1e6)?;
            w.end_object()
        }),
        EvKind::Mark(m, id) => p.emit(|w| {
            w.begin_object()?;
            w.key("name")?;
            w.string(m.name())?;
            w.key("ph")?;
            w.string("i")?;
            w.key("s")?;
            w.string("t")?;
            w.key("pid")?;
            w.num(pid)?;
            w.key("tid")?;
            w.num(tid)?;
            w.key("ts")?;
            w.num(ts)?;
            w.key("args")?;
            w.begin_object()?;
            w.key("id")?;
            w.num_u64(id)?;
            w.end_object()?;
            w.end_object()
        }),
        EvKind::Counter(v) => {
            p.emit(|w| w.counter_track("queue-depth", ev.pid as u64, ts, "depth", v))
        }
    }
}

/// The tracing sink handle. `Off` (the default) is a no-op unit arm —
/// every emission method returns immediately without touching memory —
/// so untraced runs pay one enum discriminant test per call site.
/// Cloning shares the underlying recorder (the decoupled baseline
/// clones one handle into both fleets; the simulator is
/// single-threaded, so `Rc<RefCell<_>>` suffices).
#[derive(Clone, Default)]
pub enum TraceLog {
    #[default]
    Off,
    On(Rc<RefCell<TraceState>>),
}

impl TraceLog {
    /// Recording sink (ring buffer + aggregation) without a Perfetto
    /// stream — what `annotate_report`-level observability needs.
    pub fn recording() -> TraceLog {
        TraceLog::On(Rc::new(RefCell::new(TraceState::default())))
    }

    /// Recording sink that additionally streams Chrome trace events to
    /// `out` in constant memory. The stream is a single JSON array,
    /// closed by [`TraceLog::finish_perfetto`].
    pub fn with_perfetto(out: Box<dyn io::Write>) -> TraceLog {
        let mut p = Perfetto { w: JsonWriter::new(out), err: None };
        p.emit(|w| w.begin_array());
        let st = TraceState { perfetto: Some(p), ..TraceState::default() };
        TraceLog::On(Rc::new(RefCell::new(st)))
    }

    pub fn is_on(&self) -> bool {
        matches!(self, TraceLog::On(_))
    }

    fn with(&self, f: impl FnOnce(&mut TraceState)) {
        if let TraceLog::On(st) = self {
            f(&mut st.borrow_mut());
        }
    }

    // -- lifecycle emission ---------------------------------------------

    pub fn arrival(&self, t: f64, id: u64) {
        self.with(|st| {
            st.ckpts.insert(
                id,
                Ckpt { arrival: t, enc_start: f64::NAN, enc_done: f64::NAN, pref_start: f64::NAN },
            );
            st.record(Ev { t, pid: 0, tid: 0, kind: EvKind::Mark(Mark::Arrival, id) });
        });
    }

    pub fn mark(&self, t: f64, pid: u32, tid: u32, m: Mark, id: u64) {
        self.with(|st| st.record(Ev { t, pid, tid, kind: EvKind::Mark(m, id) }));
    }

    pub fn span_begin(&self, t: f64, pid: u32, tid: u32, k: SpanKind) {
        self.with(|st| st.record(Ev { t, pid, tid, kind: EvKind::Begin(k) }));
    }

    pub fn span_end(&self, t: f64, pid: u32, tid: u32, k: SpanKind) {
        self.with(|st| st.record(Ev { t, pid, tid, kind: EvKind::End(k) }));
    }

    pub fn window(&self, t: f64, dur: f64, pid: u32, tid: u32, k: WindowKind) {
        self.with(|st| st.record(Ev { t, pid, tid, kind: EvKind::Window(k, dur) }));
    }

    /// Queue-depth counter sample for group `pid` (feeds both the
    /// Perfetto counter track and the bounded depth time-series).
    pub fn queue_depth(&self, t: f64, pid: u32, depth: usize) {
        self.with(|st| {
            st.queue_depth.entry(pid).or_default().sample(t, depth as f64);
            st.record(Ev { t, pid, tid: 0, kind: EvKind::Counter(depth as f64) });
        });
    }

    /// Productive GPU-busy attribution: `gpus` busy for `dur` starting
    /// at `t` in group `pid` (excludes reshard shadows — see
    /// [`TraceLog::reshard_window`]).
    pub fn busy(&self, pid: u32, t: f64, dur: f64, gpus: usize) {
        self.with(|st| st.gpu_busy.entry(pid).or_default().add(t, dur, gpus as f64));
    }

    /// TP reshard busy window: opens the `Reshard` span (its `E` comes
    /// from the reshard iteration completing) and attributes the shadow
    /// (GPUs serving nothing while weights re-shard).
    pub fn reshard_window(&self, t: f64, dur: f64, pid: u32, tid: u32, gpus: usize) {
        self.with(|st| {
            st.reshard_busy_gpu_s += dur * gpus as f64;
            st.reshard_windows += 1;
            st.record(Ev { t, pid, tid, kind: EvKind::Begin(SpanKind::Reshard) });
        });
    }

    /// Unified-timeline entry for a TP merge/split (also mirrored into
    /// the report's `tp_timeline` by the coordinator).
    pub fn tp_reconfig(&self, e: &TpReconfig) {
        self.with(|st| {
            st.tp_events += 1;
            let m = if e.merge { Mark::TpMerge } else { Mark::TpSplit };
            st.record(Ev {
                t: e.t,
                pid: e.group as u32,
                tid: e.instance as u32,
                kind: EvKind::Mark(m, e.tp_after as u64),
            });
        });
    }

    // -- TTFT checkpoints ------------------------------------------------

    pub fn ckpt_encode_start(&self, t: f64, id: u64) {
        self.with(|st| {
            if let Some(c) = st.ckpts.get_mut(&id) {
                if c.enc_start.is_nan() {
                    c.enc_start = t;
                }
            }
        });
    }

    pub fn ckpt_encode_done(&self, t: f64, id: u64) {
        self.with(|st| {
            if let Some(c) = st.ckpts.get_mut(&id) {
                if c.enc_done.is_nan() {
                    c.enc_done = t;
                }
            }
        });
    }

    pub fn ckpt_prefill_start(&self, t: f64, id: u64) {
        self.with(|st| {
            if let Some(c) = st.ckpts.get_mut(&id) {
                if c.pref_start.is_nan() {
                    c.pref_start = t;
                }
            }
        });
    }

    /// First token: emits the mark and finalizes this request's TTFT
    /// decomposition (checkpoints are pruned here).
    pub fn first_token(&self, t: f64, pid: u32, tid: u32, id: u64) {
        self.with(|st| {
            if let Some(ck) = st.ckpts.remove(&id) {
                st.decomp.push(decompose(id, ck, t));
            }
            st.record(Ev { t, pid, tid, kind: EvKind::Mark(Mark::FirstToken, id) });
        });
    }

    // -- inspection ------------------------------------------------------

    /// Total events recorded so far (including those rotated out of the
    /// ring).
    pub fn events_recorded(&self) -> u64 {
        match self {
            TraceLog::Off => 0,
            TraceLog::On(st) => st.borrow().ring.total,
        }
    }

    /// Last `n` ring events as human-readable one-liners, oldest first.
    pub fn tail_lines(&self, n: usize) -> Vec<String> {
        match self {
            TraceLog::Off => Vec::new(),
            TraceLog::On(st) => st.borrow().ring.tail(n).iter().map(Ev::line).collect(),
        }
    }

    /// Finalized per-request TTFT decompositions (first-token order).
    pub fn decomp_records(&self) -> Vec<DecompRec> {
        match self {
            TraceLog::Off => Vec::new(),
            TraceLog::On(st) => st.borrow().decomp.clone(),
        }
    }

    /// Fold the aggregated samples into `rep.observability`. A no-op on
    /// `Off`, so untraced Reports stay byte-identical to pre-recorder
    /// output. Deterministic: every map is a `BTreeMap` and the
    /// decomposition vector follows first-token order.
    pub fn fold_into_report(&self, rep: &mut Report) {
        let TraceLog::On(st) = self else { return };
        let st = st.borrow();
        let n = st.decomp.len();
        let (mut q, mut e, mut p) = (0.0, 0.0, 0.0);
        for d in &st.decomp {
            q += d.queue_s;
            e += d.encode_s;
            p += d.prefill_s;
        }
        let ttft_total = q + e + p;
        let share = |x: f64| if ttft_total > 0.0 { x / ttft_total } else { 0.0 };
        let series_map = |m: &BTreeMap<u32, TimeSeries>, key: &str| {
            Json::Obj(
                m.iter().map(|(g, ts)| (g.to_string(), ts.to_json(key))).collect(),
            )
        };
        let depth_series: BTreeMap<u32, TimeSeries> =
            st.queue_depth.iter().map(|(&g, s)| (g, s.series.clone())).collect();
        rep.observability = Some(Json::obj(vec![
            (
                "ttft_decomposition",
                Json::obj(vec![
                    ("requests", Json::u64(n as u64)),
                    ("queue_s", Json::num(q)),
                    ("encode_s", Json::num(e)),
                    ("prefill_s", Json::num(p)),
                    ("queue_share", Json::num(share(q))),
                    ("encode_share", Json::num(share(e))),
                    ("prefill_share", Json::num(share(p))),
                ]),
            ),
            ("gpu_busy", series_map(&st.gpu_busy, "gpu_seconds")),
            ("queue_depth", series_map(&depth_series, "depth_seconds")),
            (
                "reshard",
                Json::obj(vec![
                    ("busy_gpu_seconds", Json::num(st.reshard_busy_gpu_s)),
                    ("windows", Json::u64(st.reshard_windows)),
                    ("timeline_events", Json::u64(st.tp_events)),
                ]),
            ),
            ("events", Json::u64(st.ring.total)),
        ]));
    }

    /// Close the Perfetto stream (ends the JSON array, flushes) and
    /// return the bytes written. Errors stashed during emission surface
    /// here. Idempotent: returns 0 if no stream was attached or it was
    /// already finished.
    pub fn finish_perfetto(&self) -> io::Result<u64> {
        let TraceLog::On(st) = self else { return Ok(0) };
        let Some(mut p) = st.borrow_mut().perfetto.take() else { return Ok(0) };
        if let Some(e) = p.err.take() {
            return Err(e);
        }
        p.w.end_array()?;
        let bytes = p.w.bytes_written();
        p.w.finish()?;
        Ok(bytes)
    }
}

// -- stall-panic formatting ----------------------------------------------

/// One formatting helper for every stall diagnostic: the phase
/// histogram, the event-queue pressure line, and (when a recorder is
/// attached) the flight-recorder tail. The `"simulation stalled"` and
/// `"outstanding by phase:"` prefixes are load-bearing — driver tests
/// and downstream tooling match on them.
pub fn format_stall(
    finished: usize,
    total: usize,
    detail: &str,
    phases: &[(&'static str, usize)],
    qt: &QueueTelemetry,
    tail: &[String],
) -> String {
    let mut msg = format!("simulation stalled: {finished}/{total} requests finished{detail}");
    if phases.is_empty() {
        msg.push_str(" (no phase breakdown available)");
    } else {
        msg.push_str("; outstanding by phase:");
        for (name, count) in phases {
            msg.push_str(&format!(" {name}={count}"));
        }
    }
    msg.push_str(&format!(
        "; event-queue pressure: pushes={} pops={} peak_pending={} cascades={}",
        qt.pushes, qt.pops, qt.peak_pending, qt.overflow_cascades
    ));
    if !tail.is_empty() {
        msg.push_str(&format!("; last {} trace events:", tail.len()));
        for line in tail {
            msg.push_str("\n  ");
            msg.push_str(line);
        }
    }
    msg
}

// -- Perfetto validation -------------------------------------------------

/// Well-formedness summary returned by [`validate_perfetto`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PerfettoSummary {
    pub events: u64,
    pub spans: u64,
    pub windows: u64,
    pub instants: u64,
    pub counters: u64,
}

/// Stream-validate a Chrome trace-event file through [`JsonReader`]
/// (constant memory): every `B` has a matching same-name `E` on its
/// (pid, tid) track with valid nesting, timestamps are monotone per
/// track, and no span is left open at EOF. Returns per-phase counts.
pub fn validate_perfetto<R: io::Read>(src: R) -> Result<PerfettoSummary, String> {
    let mut r = JsonReader::new(src);
    let jerr = |e: crate::util::json::JsonError| format!("trace parse: {e}");
    match r.next_event().map_err(jerr)? {
        Some(JsonEvent::BeginArray) => {}
        other => return Err(format!("expected top-level array, got {other:?}")),
    }
    let mut sum = PerfettoSummary::default();
    let mut open: BTreeMap<(u64, u64), Vec<&'static str>> = BTreeMap::new();
    let mut last_ts: BTreeMap<(u64, u64), f64> = BTreeMap::new();
    let span_names: [&'static str; 4] = ["encode", "prefill", "decode", "reshard"];
    loop {
        match r.next_event().map_err(jerr)? {
            Some(JsonEvent::EndArray) => break,
            Some(JsonEvent::BeginObject) => {}
            other => return Err(format!("expected trace event object, got {other:?}")),
        }
        let (mut ph, mut name) = (String::new(), String::new());
        let (mut pid, mut tid, mut ts) = (0u64, 0u64, f64::NAN);
        loop {
            match r.next_event().map_err(jerr)? {
                Some(JsonEvent::EndObject) => break,
                Some(JsonEvent::Key(k)) => {
                    let key = k.to_string();
                    match key.as_str() {
                        "ph" | "name" | "s" => {
                            let Some(JsonEvent::Str(v)) = r.next_event().map_err(jerr)? else {
                                return Err(format!("event key {key}: expected string"));
                            };
                            if key == "ph" {
                                ph = v.to_string();
                            } else if key == "name" {
                                name = v.to_string();
                            }
                        }
                        "pid" | "tid" | "ts" | "dur" => {
                            let Some(JsonEvent::Num(v)) = r.next_event().map_err(jerr)? else {
                                return Err(format!("event key {key}: expected number"));
                            };
                            match key.as_str() {
                                "pid" => pid = v as u64,
                                "tid" => tid = v as u64,
                                "ts" => ts = v,
                                _ => {}
                            }
                        }
                        _ => r.skip_value().map_err(jerr)?,
                    }
                }
                other => return Err(format!("expected key in trace event, got {other:?}")),
            }
        }
        if !ts.is_finite() {
            return Err(format!("event #{}: missing/invalid ts", sum.events));
        }
        sum.events += 1;
        let track = (pid, tid);
        if let Some(&prev) = last_ts.get(&track) {
            if ts < prev {
                return Err(format!(
                    "track pid={pid}/tid={tid}: ts went backwards ({ts} after {prev})"
                ));
            }
        }
        last_ts.insert(track, ts);
        match ph.as_str() {
            "B" => {
                let Some(&n) = span_names.iter().find(|&&n| n == name) else {
                    return Err(format!("unknown span name `{name}`"));
                };
                open.entry(track).or_default().push(n);
                sum.spans += 1;
            }
            "E" => match open.get_mut(&track).and_then(Vec::pop) {
                Some(expect) if expect == name => {}
                Some(expect) => {
                    return Err(format!(
                        "track pid={pid}/tid={tid}: E `{name}` closes open `{expect}`"
                    ))
                }
                None => {
                    return Err(format!("track pid={pid}/tid={tid}: E `{name}` with no open span"))
                }
            },
            "X" => sum.windows += 1,
            "i" => sum.instants += 1,
            "C" => sum.counters += 1,
            other => return Err(format!("unknown ph `{other}`")),
        }
    }
    if r.next_event().map_err(jerr)?.is_some() {
        return Err("trailing content after top-level array".to_string());
    }
    for ((pid, tid), stack) in &open {
        if let Some(name) = stack.last() {
            return Err(format!("track pid={pid}/tid={tid}: span `{name}` never closed"));
        }
    }
    Ok(sum)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_is_inert() {
        let tl = TraceLog::default();
        assert!(!tl.is_on());
        tl.arrival(0.0, 1);
        tl.span_begin(0.0, 0, 0, SpanKind::Prefill);
        tl.queue_depth(0.0, 0, 3);
        tl.first_token(1.0, 0, 0, 1);
        assert_eq!(tl.events_recorded(), 0);
        assert!(tl.tail_lines(8).is_empty());
        assert!(tl.decomp_records().is_empty());
        let mut rep = Report::new(Vec::new());
        tl.fold_into_report(&mut rep);
        assert!(rep.observability.is_none());
        assert_eq!(tl.finish_perfetto().unwrap(), 0);
    }

    #[test]
    fn ring_keeps_last_events_in_order() {
        let tl = TraceLog::recording();
        for i in 0..(RING_CAP as u64 + 10) {
            tl.mark(i as f64, 0, 0, Mark::Arrival, i);
        }
        assert_eq!(tl.events_recorded(), RING_CAP as u64 + 10);
        let tail = tl.tail_lines(4);
        assert_eq!(tail.len(), 4);
        // Oldest-first, ending at the newest event.
        assert!(tail[0].contains(&format!("id={}", RING_CAP as u64 + 6)), "{tail:?}");
        assert!(tail[3].contains(&format!("id={}", RING_CAP as u64 + 9)), "{tail:?}");
    }

    #[test]
    fn time_series_coarsens_and_preserves_integral() {
        let mut ts = TimeSeries::default();
        // 10 gpu-seconds spread over [0, 5).
        ts.add(0.0, 5.0, 2.0);
        assert!((ts.total() - 10.0).abs() < 1e-9);
        // Far beyond 64 buckets at the initial 0.5 s width: coarsens.
        ts.add(1000.0, 1.0, 3.0);
        assert!(ts.values().len() <= MAX_BUCKETS);
        assert!((ts.total() - 13.0).abs() < 1e-9);
        assert!(ts.bucket_width() > 0.5);
    }

    #[test]
    fn decomposition_telescopes_to_ttft() {
        let ck = Ckpt { arrival: 1.0, enc_start: 1.5, enc_done: 2.5, pref_start: 3.0 };
        let d = decompose(7, ck, 4.0);
        assert_eq!(d.ttft_s, 3.0);
        assert!((d.queue_s - 1.0).abs() < 1e-12); // (1.5-1.0) + (3.0-2.5)
        assert!((d.encode_s - 1.0).abs() < 1e-12);
        assert!((d.prefill_s - 1.0).abs() < 1e-12);
        let sum = d.queue_s + d.encode_s + d.prefill_s;
        assert!((sum - d.ttft_s).abs() < 1e-9);
        // Text request: no encode checkpoints — everything splits
        // between queue and prefill.
        let ck = Ckpt { arrival: 0.0, enc_start: f64::NAN, enc_done: f64::NAN, pref_start: 2.0 };
        let d = decompose(8, ck, 5.0);
        assert_eq!(d.encode_s, 0.0);
        assert!((d.queue_s - 2.0).abs() < 1e-12);
        assert!((d.prefill_s - 3.0).abs() < 1e-12);
        // Out-of-order stamp (prefill recorded before encode done):
        // clamping keeps every share non-negative and the sum exact.
        let ck = Ckpt { arrival: 0.0, enc_start: 1.0, enc_done: 3.0, pref_start: 2.0 };
        let d = decompose(9, ck, 4.0);
        assert!(d.queue_s >= 0.0 && d.encode_s >= 0.0 && d.prefill_s >= 0.0);
        assert!((d.queue_s + d.encode_s + d.prefill_s - d.ttft_s).abs() < 1e-9);
    }

    #[test]
    fn first_token_finalizes_and_prunes_checkpoints() {
        let tl = TraceLog::recording();
        tl.arrival(1.0, 42);
        tl.ckpt_encode_start(1.2, 42);
        tl.ckpt_encode_done(1.8, 42);
        tl.ckpt_prefill_start(2.0, 42);
        tl.first_token(2.5, 0, 0, 42);
        let recs = tl.decomp_records();
        assert_eq!(recs.len(), 1);
        assert!((recs[0].ttft_s - 1.5).abs() < 1e-12);
        let sum = recs[0].queue_s + recs[0].encode_s + recs[0].prefill_s;
        assert!((sum - recs[0].ttft_s).abs() < 1e-9);
        // Second first-token for the same id: checkpoints already
        // pruned, no duplicate record.
        tl.first_token(3.0, 0, 0, 42);
        assert_eq!(tl.decomp_records().len(), 1);
    }

    #[test]
    fn fold_into_report_is_deterministic_and_sorted() {
        let mk = || {
            let tl = TraceLog::recording();
            tl.arrival(0.0, 1);
            tl.ckpt_prefill_start(1.0, 1);
            tl.first_token(2.0, 0, 3, 1);
            tl.busy(1, 0.0, 2.0, 4);
            tl.queue_depth(0.0, 0, 2);
            tl.queue_depth(1.5, 0, 0);
            tl.reshard_window(0.5, 0.25, 1, 2, 2);
            let mut rep = Report::new(Vec::new());
            tl.fold_into_report(&mut rep);
            rep
        };
        let (a, b) = (mk(), mk());
        let obs = a.observability.as_ref().expect("observability folded");
        assert_eq!(obs.to_string(), b.observability.as_ref().unwrap().to_string());
        let reshard = obs.get("reshard").unwrap();
        assert!((reshard.get("busy_gpu_seconds").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-12);
        let depth = obs.get("queue_depth").unwrap().get("0").unwrap();
        // 2 requests deep for 1.5 s.
        let total: f64 = depth
            .get("depth_seconds")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .sum();
        assert!((total - 3.0).abs() < 1e-9, "depth integral {total}");
    }

    #[test]
    fn perfetto_stream_validates_and_is_deterministic() {
        let emit = || {
            let buf: Rc<RefCell<Vec<u8>>> = Rc::default();
            struct Sink(Rc<RefCell<Vec<u8>>>);
            impl io::Write for Sink {
                fn write(&mut self, b: &[u8]) -> io::Result<usize> {
                    self.0.borrow_mut().extend_from_slice(b);
                    Ok(b.len())
                }
                fn flush(&mut self) -> io::Result<()> {
                    Ok(())
                }
            }
            let tl = TraceLog::with_perfetto(Box::new(Sink(buf.clone())));
            tl.arrival(0.0, 1);
            tl.span_begin(0.1, 0, 2, SpanKind::Encode);
            tl.span_end(0.2, 0, 2, SpanKind::Encode);
            tl.span_begin(0.3, 0, 2, SpanKind::Prefill);
            tl.span_end(0.5, 0, 2, SpanKind::Prefill);
            tl.window(0.6, 0.3, 0, 2, WindowKind::DecodeFastForward);
            tl.queue_depth(0.7, 0, 4);
            tl.first_token(0.8, 0, 2, 1);
            tl.finish_perfetto().unwrap();
            let out = buf.borrow().clone();
            out
        };
        let (a, b) = (emit(), emit());
        assert_eq!(a, b, "same emission sequence must stream identical bytes");
        let sum = validate_perfetto(&a[..]).unwrap();
        assert_eq!(sum.spans, 2);
        assert_eq!(sum.windows, 1);
        assert_eq!(sum.counters, 1);
        assert!(sum.instants >= 2);
    }

    #[test]
    fn perfetto_validator_rejects_malformed_streams() {
        // Unbalanced: B without E.
        let s = br#"[{"name":"prefill","ph":"B","pid":0,"tid":1,"ts":0}]"#;
        assert!(validate_perfetto(&s[..]).unwrap_err().contains("never closed"));
        // E without B.
        let s = br#"[{"name":"prefill","ph":"E","pid":0,"tid":1,"ts":0}]"#;
        assert!(validate_perfetto(&s[..]).unwrap_err().contains("no open span"));
        // Bad nesting: inner span closed with the outer's name.
        let s = br#"[{"name":"prefill","ph":"B","pid":0,"tid":1,"ts":0},
                     {"name":"encode","ph":"B","pid":0,"tid":1,"ts":1},
                     {"name":"prefill","ph":"E","pid":0,"tid":1,"ts":2},
                     {"name":"encode","ph":"E","pid":0,"tid":1,"ts":3}]"#;
        assert!(validate_perfetto(&s[..]).unwrap_err().contains("closes open"));
        // Non-monotone timestamps on one track.
        let s = br#"[{"name":"decode","ph":"B","pid":0,"tid":1,"ts":5},
                     {"name":"decode","ph":"E","pid":0,"tid":1,"ts":4}]"#;
        assert!(validate_perfetto(&s[..]).unwrap_err().contains("backwards"));
    }

    #[test]
    fn stall_formatting_keeps_pinned_text_and_appends_tail() {
        let qt = QueueTelemetry { pushes: 10, pops: 9, peak_pending: 4, overflow_cascades: 1 };
        // No phase breakdown, no tail — the legacy shape.
        let msg = format_stall(3, 5, " (driver detail)", &[], &qt, &[]);
        assert!(msg.contains("simulation stalled: 3/5 requests finished (driver detail)"));
        assert!(msg.contains(" (no phase breakdown available)"));
        assert!(msg.contains("event-queue pressure: pushes=10 pops=9 peak_pending=4 cascades=1"));
        // Phase histogram + flight-recorder tail.
        let tail = vec!["t=    1.0000 g0/i1 B prefill".to_string()];
        let msg = format_stall(0, 2, "", &[("Dropped", 1), ("Decoding", 1)], &qt, &tail);
        assert!(msg.contains("outstanding by phase: Dropped=1 Decoding=1"));
        assert!(msg.contains("last 1 trace events:"));
        assert!(msg.contains("\n  t=    1.0000 g0/i1 B prefill"));
    }

    #[test]
    fn tp_reconfig_round_trips_through_json() {
        let e = TpReconfig { t: 1.5, group: 2, instance: 3, tp_after: 4, merge: true };
        let j = e.to_json();
        assert_eq!(j.get("t").unwrap().as_f64().unwrap(), 1.5);
        assert_eq!(j.get("tp_after").unwrap().as_u64().unwrap(), 4);
        assert_eq!(j.get("merge").unwrap(), &Json::Bool(true));
    }
}
