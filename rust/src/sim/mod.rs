//! Discrete-event cluster simulator — the testbed substitute
//! (DESIGN.md §Substitutions). [`engine`] provides the clock/queue,
//! [`instance`] the elastic-instance and request state shared by the
//! EMP coordinator and all baselines, and [`driver`] the shared
//! [`driver::ServingSystem`] trait plus the generic trace driver every
//! system runs on. [`sweep`] fans grids of independent runs across
//! threads with deterministic, worker-count-invariant aggregation.

pub mod driver;
pub mod engine;
pub mod instance;
pub mod slab;
pub mod sweep;
pub mod tracelog;
