//! Parallel deterministic sweep engine (DESIGN.md §8).
//!
//! The paper's headline numbers come from sweeping configurations across
//! datasets and load levels; answering production questions ("what
//! `--gpus/--max-tp/--groups` config survives a 10x flash crowd?") needs
//! hundreds of runs. This module turns the simulator's *per-run*
//! determinism (the shared [`crate::sim::driver`] event loop) into
//! *wall-clock* throughput: a [`SweepSpec`] describes a cartesian grid of
//! {system variant × scaling policy × dataset × arrival scale × seed},
//! the grid is pre-expanded into self-contained [`RunPoint`]s, and `std::thread`
//! workers drain an atomic-index work queue, each constructing its own
//! [`ServingSystem`](crate::sim::driver::ServingSystem) + trace so
//! nothing is shared mutably.
//!
//! **Determinism rule**: results land in a pre-sized slot vector by run
//! index, every per-run seed is a pure function of
//! `(master_seed, stream_id)` (see [`crate::util::rng::stream_seed`]),
//! and the aggregate JSON ([`SweepOutcome::deterministic_json`]) carries
//! no wall-clock data — so worker count and OS scheduling can never
//! change the output byte stream (asserted by
//! `rust/tests/sweep_determinism.rs`).
//!
//! **Paired comparisons**: the trace stream id depends only on
//! `(dataset, qps_scale, seed)` — *not* on the variant or policy — so
//! every system variant and scaling policy at a grid point replays the
//! identical trace (common random numbers), which slashes the variance
//! of cross-variant deltas.

use crate::baselines::coupled::CoupledVllm;
use crate::baselines::decoupled::DecoupledStatic;
use crate::config::{presets, GpuSpec, SchedulerConfig};
use crate::coordinator::{policy, EmpOptions, EmpSystem, Foresight};
use crate::metrics::{pareto_frontier, RunMetrics};
use crate::model::CostModel;
use crate::sim::driver::run_trace_with_stats;
use crate::util::bench::fnv1a64;
use crate::util::json::Json;
use crate::util::rng::stream_seed;
use crate::workload::datasets::DatasetSpec;
use crate::workload::Request;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};

/// The serving-system variants a sweep can compare. Each maps to the
/// same constructions `main.rs`'s `simulate` subcommand performs, so a
/// sweep run is bit-for-bit reproducible as a single `simulate` run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Full ElasticMM ([`EmpOptions::full`] / [`EmpOptions::full_nway`])
    /// with elastic TP up to `max_tp`.
    Emp { nway: bool, max_tp: usize },
    /// Elasticity-frozen split ([`EmpOptions::static_split`]).
    StaticSplit,
    /// Coupled vLLM-style baseline.
    Coupled,
    /// Decoupled static encode/LLM baseline.
    Decoupled,
}

impl Variant {
    /// Canonical variant names, for CLI parsing and error messages.
    pub const REGISTRY: [&'static str; 7] =
        ["emp", "emp-nway", "emp-tp2", "emp-tp4", "static", "vllm", "vllm-decouple"];

    /// Look up a variant by registry name. `None` means unknown —
    /// callers must error out, not fall back.
    pub fn by_name(name: &str) -> Option<Variant> {
        match name {
            "emp" => Some(Variant::Emp { nway: false, max_tp: 1 }),
            "emp-nway" => Some(Variant::Emp { nway: true, max_tp: 1 }),
            "emp-tp2" => Some(Variant::Emp { nway: false, max_tp: 2 }),
            "emp-tp4" => Some(Variant::Emp { nway: false, max_tp: 4 }),
            "static" => Some(Variant::StaticSplit),
            "vllm" => Some(Variant::Coupled),
            "vllm-decouple" => Some(Variant::Decoupled),
            _ => None,
        }
    }
}

/// The sweep's fixed cost model (Table-1 reference config): every run
/// prices on Qwen2.5-VL-7B over A800-80G, matching `simulate` defaults.
fn sweep_cost_model() -> CostModel {
    CostModel::new(presets::qwen25_vl_7b(), GpuSpec::a800_80g())
}

/// Grid definition: the cartesian product
/// `variants × datasets × qps_scales × seeds` expands to
/// [`SweepSpec::expand`]'s run list in variant-major order.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Master seed; every run's seed is forked from it per-stream.
    pub master_seed: u64,
    /// Independent seed replicates per (variant, dataset, qps) point.
    pub seeds: usize,
    /// Dataset registry names ([`DatasetSpec::REGISTRY`]).
    pub datasets: Vec<String>,
    /// Variant registry names ([`Variant::REGISTRY`]).
    pub variants: Vec<String>,
    /// Scaling-policy registry names
    /// ([`crate::coordinator::policy::REGISTRY`]). Applied to the
    /// EMP-family variants; the vLLM baselines have no policy surface
    /// and replay identically under every policy value.
    pub policies: Vec<String>,
    /// Arrival-rate multipliers applied to `base_qps`.
    pub qps_scales: Vec<f64>,
    pub base_qps: f64,
    /// Requests per run.
    pub requests: usize,
    /// GPUs per simulated cluster (also the GPU-hours cost basis).
    pub gpus: usize,
}

impl SweepSpec {
    /// CI-sized grid: 2 variants × 2 policies × 2 datasets × 2 load
    /// levels × 2 seeds = 32 runs, small enough to finish in seconds
    /// yet wide enough to exercise every aggregation path (the oracle
    /// is excluded here and exercised by the full grid and the policy
    /// shoot-out bench).
    pub fn smoke() -> SweepSpec {
        SweepSpec {
            master_seed: 42,
            seeds: 2,
            datasets: vec!["sharegpt".to_string(), "mixed-modal".to_string()],
            variants: vec!["emp".to_string(), "vllm".to_string()],
            policies: vec!["reactive".to_string(), "predictive".to_string()],
            qps_scales: vec![1.0, 2.0],
            base_qps: 4.0,
            requests: 120,
            gpus: 8,
        }
    }

    /// Default exploration grid: 5 variants × 3 policies × 4 datasets ×
    /// 3 load levels × 3 seeds = 540 runs — a Fig 6/7-style sweep plus
    /// the policy shoot-out axes (flash-crowd dataset, all three
    /// scaling policies).
    pub fn default_grid() -> SweepSpec {
        SweepSpec {
            master_seed: 42,
            seeds: 3,
            datasets: vec![
                "sharegpt".to_string(),
                "vwi".to_string(),
                "mixed-modal".to_string(),
                "flash-crowd".to_string(),
            ],
            variants: vec![
                "emp".to_string(),
                "emp-tp4".to_string(),
                "static".to_string(),
                "vllm".to_string(),
                "vllm-decouple".to_string(),
            ],
            policies: vec![
                "reactive".to_string(),
                "predictive".to_string(),
                "oracle".to_string(),
            ],
            qps_scales: vec![0.5, 1.0, 2.0],
            base_qps: 6.0,
            requests: 300,
            gpus: 8,
        }
    }

    /// Reject malformed grids before any thread spawns.
    pub fn validate(&self) -> Result<(), String> {
        if self.seeds == 0 {
            return Err("seeds must be >= 1".to_string());
        }
        if self.requests == 0 {
            return Err("requests must be >= 1".to_string());
        }
        if self.datasets.is_empty() {
            return Err("at least one dataset required".to_string());
        }
        for d in &self.datasets {
            if DatasetSpec::by_name(d).is_none() {
                return Err(format!(
                    "unknown dataset `{d}`; valid: {}",
                    DatasetSpec::REGISTRY.join(", ")
                ));
            }
        }
        if self.variants.is_empty() {
            return Err("at least one variant required".to_string());
        }
        for v in &self.variants {
            if Variant::by_name(v).is_none() {
                return Err(format!(
                    "unknown variant `{v}`; valid: {}",
                    Variant::REGISTRY.join(", ")
                ));
            }
        }
        if self.policies.is_empty() {
            return Err("at least one policy required".to_string());
        }
        for p in &self.policies {
            if !policy::REGISTRY.contains(&p.as_str()) {
                return Err(format!(
                    "unknown policy `{p}`; valid: {}",
                    policy::REGISTRY.join(", ")
                ));
            }
        }
        if self.qps_scales.is_empty() {
            return Err("at least one qps scale required".to_string());
        }
        for &q in &self.qps_scales {
            if !q.is_finite() || q <= 0.0 {
                return Err(format!("qps scales must be positive, got {q}"));
            }
        }
        if !self.base_qps.is_finite() || self.base_qps <= 0.0 {
            return Err(format!("base qps must be positive, got {}", self.base_qps));
        }
        // Instances, not raw GPUs: an instance spans the model's minimum
        // TP degree worth of GPUs (mirrors `simulate`'s validation).
        let instances = self.gpus / sweep_cost_model().min_tp().max(1);
        if instances < 2 {
            return Err(format!("{} GPUs give {instances} instances; need >= 2", self.gpus));
        }
        for v in &self.variants {
            if Variant::by_name(v) == Some(Variant::Emp { nway: true, max_tp: 1 })
                && instances < 4
            {
                return Err(format!(
                    "variant `{v}` needs >= 4 instances (one per modality group); \
                     {} GPUs give only {instances}",
                    self.gpus
                ));
            }
        }
        Ok(())
    }

    /// Expand the grid into self-contained run points, variant-major
    /// then policy-major:
    /// `for variant { for policy { for dataset { for qps { for seed } } } }`.
    /// The trace stream id is a pure function of
    /// `(dataset, qps_scale, seed)` so all variants and policies at a
    /// grid point share one trace (paired comparisons).
    pub fn expand(&self) -> Vec<RunPoint> {
        let mut points = Vec::new();
        for variant in &self.variants {
            for pol in &self.policies {
                for (di, dataset) in self.datasets.iter().enumerate() {
                    for (qi, &scale) in self.qps_scales.iter().enumerate() {
                        for si in 0..self.seeds {
                            let stream =
                                (si + self.seeds * (qi + self.qps_scales.len() * di)) as u64;
                            points.push(RunPoint {
                                index: points.len(),
                                variant: variant.clone(),
                                policy: pol.clone(),
                                dataset: dataset.clone(),
                                qps_scale: scale,
                                qps: self.base_qps * scale,
                                seed_stream: stream,
                                seed: stream_seed(self.master_seed, stream),
                            });
                        }
                    }
                }
            }
        }
        points
    }

    /// Install the point's scaling policy on an EMP-family system. The
    /// reactive default is left in place untouched — it *is* the
    /// pre-policy coordinator logic and keeps fast-forward eligibility.
    fn install_policy(&self, sys: &mut EmpSystem, point: &RunPoint, trace: &[Request]) {
        if point.policy == "reactive" {
            return;
        }
        let foresight = (point.policy == "oracle").then(|| Foresight::of_trace(trace));
        sys.set_policy(policy::by_name(&point.policy, foresight).expect("validated policy"));
    }

    /// Execute one grid point to completion on the calling thread.
    /// Pure: same spec + point ⇒ same [`RunResult`] on any machine, so
    /// a sweep entry can be re-verified by running its point directly.
    pub fn run_point(&self, point: &RunPoint) -> RunResult {
        let ds = DatasetSpec::by_name(&point.dataset).expect("validated dataset");
        let trace = ds.sample_trace(self.master_seed, point.seed_stream, self.requests, point.qps);
        let cost = sweep_cost_model();
        let mut sched = SchedulerConfig::default();
        let variant = Variant::by_name(&point.variant).expect("validated variant");
        let (report, stats) = match variant {
            Variant::Emp { nway, max_tp } => {
                sched.max_tp = max_tp;
                let opts = if nway {
                    EmpOptions::full_nway(self.gpus)
                } else {
                    EmpOptions::full(self.gpus)
                };
                let mut sys = EmpSystem::new(cost, sched, self.gpus, opts);
                self.install_policy(&mut sys, point, &trace);
                run_trace_with_stats(&mut sys, &trace)
            }
            Variant::StaticSplit => {
                let opts = EmpOptions::static_split(self.gpus / 2);
                let mut sys = EmpSystem::new(cost, sched, self.gpus, opts);
                self.install_policy(&mut sys, point, &trace);
                run_trace_with_stats(&mut sys, &trace)
            }
            Variant::Coupled => {
                run_trace_with_stats(&mut CoupledVllm::new(cost, sched, self.gpus), &trace)
            }
            Variant::Decoupled => {
                run_trace_with_stats(&mut DecoupledStatic::new(cost, sched, self.gpus), &trace)
            }
        };
        RunResult {
            metrics: RunMetrics::from_report(&report, self.gpus),
            events: stats.events,
            digest: report.canonical_digest(),
            point: point.clone(),
        }
    }

    /// Run the whole grid across `threads` workers (`0` =
    /// `available_parallelism`). Workers pull run indices from one
    /// atomic counter and each result lands in its pre-assigned slot,
    /// so the output is identical at any worker count.
    pub fn run(&self, threads: usize) -> Result<SweepOutcome, String> {
        self.validate()?;
        let points = self.expand();
        let threads = effective_threads(threads, points.len());
        let t0 = std::time::Instant::now();
        let next = AtomicUsize::new(0);
        let indexed: Vec<(usize, RunResult)> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    let (next, points) = (&next, &points);
                    scope.spawn(move || {
                        let mut out = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= points.len() {
                                break;
                            }
                            out.push((i, self.run_point(&points[i])));
                        }
                        out
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("sweep worker panicked"))
                .collect()
        });
        // Deterministic ordering: slot vector by run index. Worker count
        // and scheduling decide only *who* fills a slot, never *what*.
        let mut slots: Vec<Option<RunResult>> = Vec::with_capacity(points.len());
        slots.resize_with(points.len(), || None);
        for (i, r) in indexed {
            slots[i] = Some(r);
        }
        let results = slots
            .into_iter()
            .map(|s| s.expect("every run index filled exactly once"))
            .collect();
        Ok(SweepOutcome {
            spec: self.clone(),
            results,
            threads,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("master_seed", Json::str(format!("{:016x}", self.master_seed))),
            ("seeds", Json::num(self.seeds as f64)),
            ("datasets", Json::Arr(self.datasets.iter().map(|d| Json::str(d.clone())).collect())),
            ("variants", Json::Arr(self.variants.iter().map(|v| Json::str(v.clone())).collect())),
            ("policies", Json::Arr(self.policies.iter().map(|p| Json::str(p.clone())).collect())),
            ("qps_scales", Json::Arr(self.qps_scales.iter().map(|&q| Json::num(q)).collect())),
            ("base_qps", Json::num(self.base_qps)),
            ("requests", Json::num(self.requests as f64)),
            ("gpus", Json::num(self.gpus as f64)),
        ])
    }
}

/// Resolve a requested worker count: `0` means every available core,
/// and there is never a reason to spawn more workers than runs.
pub fn effective_threads(requested: usize, runs: usize) -> usize {
    let t = if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    };
    t.clamp(1, runs.max(1))
}

/// One fully-specified cell of the expanded grid. Self-contained: a
/// worker needs nothing else (plus the spec's shared constants) to
/// execute it.
#[derive(Debug, Clone)]
pub struct RunPoint {
    /// Position in the expanded run list — the slot this run's result
    /// lands in, and its id in the aggregate JSON.
    pub index: usize,
    pub variant: String,
    /// Scaling-policy registry name (EMP-family variants only; the
    /// vLLM baselines ignore it).
    pub policy: String,
    pub dataset: String,
    pub qps_scale: f64,
    /// `base_qps * qps_scale`, precomputed.
    pub qps: f64,
    /// Trace stream id — shared by all variants at a grid point.
    pub seed_stream: u64,
    /// `stream_seed(master_seed, seed_stream)` — the actual RNG seed.
    pub seed: u64,
}

/// One completed run: scalar objectives + the event count + the
/// canonical-report digest that proves this run matches a direct
/// `run_trace` of the same configuration.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub point: RunPoint,
    pub metrics: RunMetrics,
    /// Driver events dispatched (arrivals + ticks + system events).
    pub events: u64,
    /// [`crate::metrics::Report::canonical_digest`] of the run's report.
    pub digest: u64,
}

impl RunResult {
    pub fn to_json(&self) -> Json {
        // u64 seeds/digests exceed f64's exact-integer range, so they
        // serialize as fixed-width hex strings.
        Json::obj(vec![
            ("index", Json::num(self.point.index as f64)),
            ("variant", Json::str(self.point.variant.clone())),
            ("policy", Json::str(self.point.policy.clone())),
            ("dataset", Json::str(self.point.dataset.clone())),
            ("qps_scale", Json::num(self.point.qps_scale)),
            ("qps", Json::num(self.point.qps)),
            ("seed_stream", Json::num(self.point.seed_stream as f64)),
            ("seed", Json::str(format!("{:016x}", self.point.seed))),
            ("events", Json::num(self.events as f64)),
            ("digest", Json::str(format!("{:016x}", self.digest))),
            ("metrics", self.metrics.to_json()),
        ])
    }
}

/// A finished sweep: the spec, one result per run point (in run-index
/// order), and the timing of this particular execution. Everything
/// except `threads`/`wall_s` is deterministic.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    pub spec: SweepSpec,
    pub results: Vec<RunResult>,
    pub threads: usize,
    pub wall_s: f64,
}

impl SweepOutcome {
    /// Indices of the Pareto-optimal runs over
    /// (goodput ↑, SLO attainment ↑, GPU-hours ↓).
    pub fn frontier(&self) -> Vec<usize> {
        let points: Vec<RunMetrics> = self.results.iter().map(|r| r.metrics).collect();
        pareto_frontier(&points)
    }

    /// Total driver events across all runs — the deterministic "work
    /// done" measure the bench gate puts a ceiling on.
    pub fn events_total(&self) -> u64 {
        self.results.iter().map(|r| r.events).sum()
    }

    pub fn runs_per_sec(&self) -> f64 {
        self.results.len() as f64 / self.wall_s.max(1e-9)
    }

    fn axis_marginal(&self, key: impl Fn(&RunResult) -> String) -> Json {
        let mut groups: BTreeMap<String, Vec<&RunMetrics>> = BTreeMap::new();
        for r in &self.results {
            groups.entry(key(r)).or_default().push(&r.metrics);
        }
        let mut out = BTreeMap::new();
        for (k, ms) in groups {
            let n = ms.len() as f64;
            let mean = |f: fn(&RunMetrics) -> f64| ms.iter().copied().map(f).sum::<f64>() / n;
            out.insert(
                k,
                Json::obj(vec![
                    ("runs", Json::num(n)),
                    ("mean_goodput_rps", Json::num(mean(|m| m.goodput_rps))),
                    ("mean_slo_attainment", Json::num(mean(|m| m.slo_attainment))),
                    ("mean_p99_ttft_s", Json::num(mean(|m| m.p99_ttft_s))),
                    ("mean_gpu_hours", Json::num(mean(|m| m.gpu_hours))),
                ]),
            );
        }
        Json::Obj(out)
    }

    /// Per-axis marginal means: collapse the grid onto each axis in turn
    /// — the "which knob matters" view of the sweep.
    pub fn marginals(&self) -> Json {
        Json::obj(vec![
            ("variant", self.axis_marginal(|r| r.point.variant.clone())),
            ("policy", self.axis_marginal(|r| r.point.policy.clone())),
            ("dataset", self.axis_marginal(|r| r.point.dataset.clone())),
            ("qps_scale", self.axis_marginal(|r| r.point.qps_scale.to_string())),
            ("seed_stream", self.axis_marginal(|r| r.point.seed_stream.to_string())),
        ])
    }

    /// The thread-count-invariant aggregate: spec, per-run results,
    /// Pareto frontier, and marginals — **no wall-clock or host data**.
    /// `aggregate_digest` fingerprints the body so two executions can be
    /// compared with one string. Byte-identical at any worker count.
    pub fn deterministic_json(&self) -> Json {
        let body = Json::obj(vec![
            ("spec", self.spec.to_json()),
            ("runs_total", Json::num(self.results.len() as f64)),
            ("events_total", Json::num(self.events_total() as f64)),
            ("runs", Json::Arr(self.results.iter().map(|r| r.to_json()).collect())),
            (
                "pareto_frontier",
                Json::Arr(self.frontier().into_iter().map(|i| Json::num(i as f64)).collect()),
            ),
            ("marginals", self.marginals()),
        ]);
        let digest = fnv1a64(body.to_string().as_bytes());
        let Json::Obj(mut map) = body else { unreachable!("obj built above") };
        map.insert("aggregate_digest".to_string(), Json::str(format!("{digest:016x}")));
        Json::Obj(map)
    }

    /// Full BENCH_sweep.json payload: the deterministic aggregate plus
    /// this execution's timing and the regression-gate section
    /// (`"sweep" → {mode}`) that `check_regression_section` reads.
    /// Timing keys live outside the gate section except `runs_per_sec`
    /// (floored) and the deterministic counts (ceilinged).
    pub fn bench_json(
        &self,
        mode: &str,
        wall_s_1_thread: Option<f64>,
        wall_s_4_threads: Option<f64>,
    ) -> Json {
        let Json::Obj(mut map) = self.deterministic_json() else {
            unreachable!("deterministic_json returns an object")
        };
        map.insert("bench".to_string(), Json::str("sweep"));
        let speedup = match (wall_s_1_thread, wall_s_4_threads) {
            (Some(w1), Some(w4)) if w4 > 0.0 => Some(w1 / w4),
            _ => None,
        };
        let mut timing = vec![
            ("threads", Json::num(self.threads as f64)),
            ("wall_s", Json::num(self.wall_s)),
            ("runs_per_sec", Json::num(self.runs_per_sec())),
        ];
        if let Some(w) = wall_s_1_thread {
            timing.push(("wall_s_1_thread", Json::num(w)));
        }
        if let Some(w) = wall_s_4_threads {
            timing.push(("wall_s_4_threads", Json::num(w)));
        }
        if let Some(s) = speedup {
            timing.push(("speedup_4_threads", Json::num(s)));
        }
        map.insert("timing".to_string(), Json::obj(timing));
        let mut gate = vec![
            ("runs_per_sec", Json::num(self.runs_per_sec())),
            ("runs_total", Json::num(self.results.len() as f64)),
            ("events_total", Json::num(self.events_total() as f64)),
        ];
        if let Some(s) = speedup {
            gate.push(("speedup_4_threads", Json::num(s)));
        }
        map.insert("sweep".to_string(), Json::obj(vec![(mode, Json::obj(gate))]));
        Json::Obj(map)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_registry_resolves_and_rejects() {
        for name in Variant::REGISTRY {
            assert!(Variant::by_name(name).is_some(), "registry name {name}");
        }
        assert_eq!(Variant::by_name("emp-tp4"), Some(Variant::Emp { nway: false, max_tp: 4 }));
        assert!(Variant::by_name("sglang").is_none());
    }

    #[test]
    fn smoke_and_default_specs_validate() {
        assert_eq!(SweepSpec::smoke().validate(), Ok(()));
        assert_eq!(SweepSpec::default_grid().validate(), Ok(()));
        assert_eq!(SweepSpec::smoke().expand().len(), 32);
        assert_eq!(SweepSpec::default_grid().expand().len(), 540);
    }

    #[test]
    fn validate_rejects_bad_grids() {
        let mut s = SweepSpec::smoke();
        s.datasets = vec!["not-a-dataset".to_string()];
        assert!(s.validate().unwrap_err().contains("unknown dataset"));
        let mut s = SweepSpec::smoke();
        s.variants = vec!["sglang".to_string()];
        assert!(s.validate().unwrap_err().contains("unknown variant"));
        let mut s = SweepSpec::smoke();
        s.qps_scales = vec![0.0];
        assert!(s.validate().unwrap_err().contains("positive"));
        let mut s = SweepSpec::smoke();
        s.policies = vec!["clairvoyant".to_string()];
        assert!(s.validate().unwrap_err().contains("unknown policy"));
        let mut s = SweepSpec::smoke();
        s.policies.clear();
        assert!(s.validate().unwrap_err().contains("policy"));
        let mut s = SweepSpec::smoke();
        s.seeds = 0;
        assert!(s.validate().is_err());
        let mut s = SweepSpec::smoke();
        s.variants.push("emp-nway".to_string());
        s.gpus = 2;
        assert!(s.validate().unwrap_err().contains("4 instances"));
    }

    #[test]
    fn expansion_is_variant_then_policy_major_with_shared_trace_streams() {
        let spec = SweepSpec::smoke();
        let points = spec.expand();
        assert_eq!(points.len(), 32);
        for (i, p) in points.iter().enumerate() {
            assert_eq!(p.index, i, "slot index mismatch");
            assert_eq!(p.seed, stream_seed(spec.master_seed, p.seed_stream));
            assert!((p.qps - spec.base_qps * p.qps_scale).abs() < 1e-12);
        }
        // Blocks of datasets × qps_scales × seeds = 8 runs per
        // (variant, policy) pair, variant-major then policy-major, and
        // the trace stream id is (variant, policy)-independent: run i
        // and run i + k*8 replay the same (dataset, qps, seed) trace.
        let block = spec.datasets.len() * spec.qps_scales.len() * spec.seeds;
        assert_eq!(block, 8);
        for i in 0..block {
            assert_eq!(points[i].variant, "emp");
            assert_eq!(points[i].policy, "reactive");
            assert_eq!(points[i + block].variant, "emp");
            assert_eq!(points[i + block].policy, "predictive");
            assert_eq!(points[i + 2 * block].variant, "vllm");
            assert_eq!(points[i + 2 * block].policy, "reactive");
            assert_eq!(points[i + 3 * block].variant, "vllm");
            assert_eq!(points[i + 3 * block].policy, "predictive");
            for k in 1..4 {
                assert_eq!(points[i].seed_stream, points[i + k * block].seed_stream);
                assert_eq!(points[i].seed, points[i + k * block].seed);
                assert_eq!(points[i].dataset, points[i + k * block].dataset);
            }
        }
        // Distinct (dataset, qps, seed) points get distinct streams.
        let mut streams: Vec<u64> = points[..block].iter().map(|p| p.seed_stream).collect();
        streams.sort_unstable();
        streams.dedup();
        assert_eq!(streams.len(), block, "stream ids must be unique per trace point");
    }

    #[test]
    fn effective_threads_resolution() {
        assert_eq!(effective_threads(3, 100), 3);
        assert_eq!(effective_threads(8, 2), 2, "never more workers than runs");
        assert_eq!(effective_threads(1, 0), 1);
        assert!(effective_threads(0, 100) >= 1, "0 = available parallelism");
    }

    fn fake_result(index: usize, variant: &str, goodput: f64, gpu_hours: f64) -> RunResult {
        RunResult {
            point: RunPoint {
                index,
                variant: variant.to_string(),
                policy: "reactive".to_string(),
                dataset: "sharegpt".to_string(),
                qps_scale: 1.0,
                qps: 4.0,
                seed_stream: index as u64,
                seed: stream_seed(42, index as u64),
            },
            metrics: RunMetrics {
                requests: 10,
                throughput_rps: goodput,
                goodput_rps: goodput,
                slo_attainment: 0.9,
                p99_ttft_s: 1.0,
                mean_ttft_s: 0.5,
                gpu_hours,
            },
            events: 1000,
            digest: 0xDEAD_BEEF,
        }
    }

    fn fake_outcome() -> SweepOutcome {
        SweepOutcome {
            spec: SweepSpec::smoke(),
            results: vec![
                fake_result(0, "emp", 10.0, 4.0),
                fake_result(1, "vllm", 6.0, 5.0), // dominated by run 0
            ],
            threads: 2,
            wall_s: 4.0,
        }
    }

    #[test]
    fn aggregate_excludes_wall_clock_and_digests_stably() {
        let out = fake_outcome();
        let agg = out.deterministic_json();
        assert!(agg.get("timing").is_err(), "no wall-clock in the aggregate");
        assert!(agg.get("threads").is_err());
        assert_eq!(agg.get("runs_total").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(agg.get("events_total").unwrap().as_f64().unwrap(), 2000.0);
        let frontier = agg.get("pareto_frontier").unwrap().as_arr().unwrap();
        assert_eq!(frontier.len(), 1, "run 1 is dominated");
        assert_eq!(frontier[0].as_f64().unwrap(), 0.0);
        // Identical results at a different thread count / wall time give
        // a byte-identical aggregate (the thread-invariance contract).
        let mut other = fake_outcome();
        other.threads = 1;
        other.wall_s = 99.0;
        assert_eq!(agg.to_string(), other.deterministic_json().to_string());
        // The embedded digest matches a recomputation over the body.
        let digest = agg.get("aggregate_digest").unwrap().as_str().unwrap().to_string();
        assert_eq!(digest.len(), 16);
    }

    #[test]
    fn marginals_group_by_axis_value() {
        let out = fake_outcome();
        let m = out.marginals();
        let by_variant = m.get("variant").unwrap();
        assert_eq!(by_variant.get("emp").unwrap().get("runs").unwrap().as_f64().unwrap(), 1.0);
        let g = by_variant.get("emp").unwrap().get("mean_goodput_rps").unwrap();
        assert_eq!(g.as_f64().unwrap(), 10.0);
        // Both runs share qps_scale 1.0 → one group of two.
        let by_scale = m.get("qps_scale").unwrap();
        assert_eq!(by_scale.get("1").unwrap().get("runs").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn bench_json_adds_timing_and_gate_sections() {
        let out = fake_outcome();
        let b = out.bench_json("smoke", Some(8.0), Some(2.0));
        assert_eq!(b.get("bench").unwrap().as_str().unwrap(), "sweep");
        let timing = b.get("timing").unwrap();
        assert_eq!(timing.get("threads").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(timing.get("speedup_4_threads").unwrap().as_f64().unwrap(), 4.0);
        let gate = b.get("sweep").unwrap().get("smoke").unwrap();
        assert_eq!(gate.get("runs_total").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(gate.get("runs_per_sec").unwrap().as_f64().unwrap(), 0.5);
        assert_eq!(gate.get("events_total").unwrap().as_f64().unwrap(), 2000.0);
        // Without both reference walls there is no speedup claim.
        let b = out.bench_json("smoke", None, None);
        assert!(b.get("timing").unwrap().get("speedup_4_threads").is_err());
        assert!(b.get("sweep").unwrap().get("smoke").unwrap().get("runs_total").is_ok());
    }
}
